"""AdamW, from scratch, with production knobs.

- decoupled weight decay with a mask (no decay on norms/biases/1-D params)
- global-norm gradient clipping
- fp32 master weights when params are bf16 (default), or fully-bf16
  optimizer state for memory-bound giants (Arctic) — ``state_dtype``
- optional int8 error-feedback gradient compression hook (dist.compression)

State is a pytree dataclass so it shards/checkpoints like params; the
logical axes of mu/nu/master mirror the parameter axes (ZeRO-3: optimizer
state lives wherever its parameter shard lives).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # mu/nu dtype
    master_weights: bool = True       # fp32 master copy when params != fp32
    compression: Optional[str] = None  # None | "int8_ef"


@dataclasses.dataclass
class AdamWState:
    step: Any
    mu: Any
    nu: Any
    master: Any       # fp32 params copy or None
    ef_residual: Any  # error-feedback residual or None


jax.tree_util.register_dataclass(
    AdamWState, data_fields=["step", "mu", "nu", "master", "ef_residual"],
    meta_fields=[])


def decay_mask(params) -> Any:
    """True where weight decay applies: >=2-D parameter tensors."""
    return jax.tree.map(lambda p: p.ndim >= 2, params)


def init(params, cfg: AdamWConfig) -> AdamWState:
    sdt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sdt)
    master = None
    if cfg.master_weights and any(
        p.dtype != jnp.float32 for p in jax.tree.leaves(params)
    ):
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    ef = None
    if cfg.compression == "int8_ef":
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        master=master,
        ef_residual=ef,
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def update(
    grads, state: AdamWState, params, cfg: AdamWConfig,
    lr: Optional[jax.Array] = None,
):
    """One AdamW step -> (new_params, new_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    sdt = jnp.dtype(cfg.state_dtype)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    if cfg.compression == "int8_ef":
        from ..dist.compression import ef_compress_tree

        grads, new_ef = ef_compress_tree(grads, state.ef_residual)
    else:
        new_ef = state.ef_residual

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mask = decay_mask(params)

    new_mu = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g).astype(sdt),
        state.mu, grads)
    new_nu = jax.tree.map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g).astype(sdt),
        state.nu, grads)

    ref = state.master if state.master is not None else params

    def step_param(p32, m, v, g, decay):
        p32 = p32.astype(jnp.float32)
        mh = m.astype(jnp.float32) / c1
        vh = v.astype(jnp.float32) / c2
        upd = mh / (jnp.sqrt(vh) + cfg.eps)
        if decay:
            upd = upd + cfg.weight_decay * p32
        return p32 - lr * upd

    new_ref = jax.tree.map(step_param, ref, new_mu, new_nu, grads, mask)
    new_params = jax.tree.map(lambda r, p: r.astype(p.dtype), new_ref, params)
    new_master = new_ref if state.master is not None else None

    new_state = AdamWState(step=step, mu=new_mu, nu=new_nu,
                           master=new_master, ef_residual=new_ef)
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, new_state, metrics


def state_logical_axes(state: AdamWState, param_axes) -> AdamWState:
    """Optimizer-state axes mirror parameter axes (FSDP-aligned)."""
    return AdamWState(
        step=(),
        mu=param_axes,
        nu=param_axes,
        master=param_axes if state.master is not None else None,
        ef_residual=param_axes if state.ef_residual is not None else None,
    )
