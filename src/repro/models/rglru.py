"""Griffin / RecurrentGemma recurrent block — arXiv:2402.19427.

Recurrent block:  y = W_out( GeLU(W_gate x) ⊙ RG-LRU(conv1d(W_x x)) )
RG-LRU:           r_t = σ(W_a u_t + b_a)        (recurrence gate)
                  i_t = σ(W_i u_t + b_i)        (input gate)
                  a_t = exp(-c · softplus(Λ) · r_t),  c = 8
                  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

The linear recurrence runs as ``lax.associative_scan`` over the sequence
(log-depth, XLA-parallel); decode is the O(width) single-step update.
Gate projections are dense (the paper uses block-diagonal; dense is a
strict superset — divergence noted in DESIGN.md).  Width shards over
``model``; the scan is over the (replicated) sequence dim.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from .cache import LayerCache
from .layers import Leaf, _dense_init, apply_norm, init_norm, matmul

_C = 8.0


def init_rglru_block(key, cfg) -> Dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    # Λ init so that a^c ∈ [0.9, 0.999] (paper §2.4)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _C)))  # softplus^-1
    return {
        "norm": init_norm(d, dt, cfg.norm),
        "w_gate": Leaf(_dense_init(ks[0], (d, w), d, dt), ("embed", "lru")),
        "w_x": Leaf(_dense_init(ks[1], (d, w), d, dt), ("embed", "lru")),
        "conv": Leaf(_dense_init(ks[2], (cfg.conv1d_width, w),
                                 cfg.conv1d_width, dt), ("conv_k", "lru")),
        "w_a": Leaf(_dense_init(ks[3], (w, w), w, dt), ("lru", "lru")),
        "b_a": Leaf(jnp.zeros((w,), jnp.float32), ("lru",)),
        "w_i": Leaf(_dense_init(ks[4], (w, w), w, dt), ("lru", "lru")),
        "b_i": Leaf(jnp.zeros((w,), jnp.float32), ("lru",)),
        "lam": Leaf(lam, ("lru",)),
        "w_out": Leaf(_dense_init(ks[6], (w, d), w, dt), ("lru", "embed")),
    }


def _rglru_coeffs(p, u):
    """u: (..., w) conv output -> (a, b) of h = a*h_prev + b, fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(matmul(uf, p["w_a"].astype(jnp.float32)) + p["b_a"])
    i = jax.nn.sigmoid(matmul(uf, p["w_i"].astype(jnp.float32)) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def _causal_conv(x, w):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=w.shape[1],
    )
    return out.astype(x.dtype)


def apply_rglru_block(
    p: Dict, x, cfg,
    cache: Optional[LayerCache] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, d = x.shape
    xn = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    gate = jax.nn.gelu(matmul(xn, p["w_gate"]).astype(jnp.float32))
    u = matmul(xn, p["w_x"])
    u = constrain(u, "batch", "seq_full", "lru")

    new_cache = None
    decode = cache is not None and S == 1
    if not decode:
        K = p["conv"].shape[0]
        u_tail = u[:, S - (K - 1):, :]
        u = _causal_conv(u, p["conv"])
        a, b = _rglru_coeffs(p, u)  # (B,S,w) each
        # associative scan over seq: (a2,b2)∘(a1,b1) = (a1*a2, a2*b1 + b2)
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        if cache is not None:  # prefill: expose final state for decode
            new_cache = LayerCache(kind="rglru", conv=u_tail, h=h[:, -1])
    else:
        K = p["conv"].shape[0]
        wins = jnp.concatenate([cache.conv, u[:, 0][:, None]], axis=1)
        u1 = jnp.einsum("bkc,kc->bc", wins.astype(jnp.float32),
                        p["conv"].astype(jnp.float32)).astype(u.dtype)
        a, b = _rglru_coeffs(p, u1[:, None])
        h = a[:, 0] * cache.h + b[:, 0]
        new_cache = LayerCache(kind="rglru", conv=wins[:, 1:], h=h)
        h = h[:, None]

    y = (gate * h).astype(x.dtype)
    return matmul(y, p["w_out"]), new_cache


