"""Mamba-2 block (SSD mixer) — arXiv:2405.21060.

Structure (per official mamba2 block, TP-adapted):
  norm -> in_proj (separate z/x/B/C/dt heads for clean TP sharding)
       -> causal depthwise conv1d on x, B, C
       -> SSD (Pallas kernel on TPU, chunked-matmul XLA ref elsewhere)
       -> gated RMSNorm(y * silu(z)) -> out_proj

Sharding: heads/d_inner over ``model`` (z, x, dt, A, D, norm); the shared
B/C group projections are small and replicated (ngroups=1 cannot shard).
Decode carries (conv_state, ssd_state) and costs O(state) per token — this
is what makes ``long_500k`` runnable for this family.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from ..kernels import ops
from .cache import LayerCache
from .layers import Leaf, _dense_init, apply_norm, init_norm, matmul


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_headdim
    return d_in, nheads, cfg.ssm_ngroups, cfg.ssm_state


def init_ssd_block(key, cfg) -> Dict:
    d = cfg.d_model
    d_in, H, G, N = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 9)
    # dt bias init: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[6], (H,), jnp.float32)
    dt0 = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    a0 = jax.random.uniform(ks[7], (H,), jnp.float32, 1.0, 16.0)
    return {
        "norm": init_norm(d, dt, cfg.norm),
        "wz": Leaf(_dense_init(ks[0], (d, d_in), d, dt), ("embed", "ssm_inner")),
        "wx": Leaf(_dense_init(ks[1], (d, d_in), d, dt), ("embed", "ssm_inner")),
        "wbc": Leaf(_dense_init(ks[2], (d, 2 * G * N), d, dt), ("embed", None)),
        "wdt": Leaf(_dense_init(ks[3], (d, H), d, dt), ("embed", "ssm_heads")),
        "conv_x": Leaf(_dense_init(ks[4], (cfg.ssm_conv, d_in), cfg.ssm_conv, dt),
                       ("conv_k", "ssm_inner")),
        "conv_bc": Leaf(_dense_init(ks[5], (cfg.ssm_conv, 2 * G * N),
                                    cfg.ssm_conv, dt), ("conv_k", None)),
        "dt_bias": Leaf(dt_bias, ("ssm_heads",)),
        "A_log": Leaf(jnp.log(a0), ("ssm_heads",)),
        "D": Leaf(jnp.ones((H,), jnp.float32), ("ssm_heads",)),
        "gnorm": Leaf(jnp.ones((d_in,), dt), ("ssm_inner",)),
        "wo": Leaf(_dense_init(ks[8], (d_in, d), d_in, dt), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w):
    """x: (B, S, C); w: (K, C) depthwise causal conv, no bias."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],  # (K, 1, C) HIO
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=w.shape[1],
    )
    return out.astype(x.dtype)


def _conv_step(x_t, state, w):
    """Single-token conv: x_t (B, C); state (B, K-1, C) past inputs."""
    K = w.shape[0]
    wins = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", wins.astype(jnp.float32),
                     w.astype(jnp.float32)).astype(x_t.dtype)
    return out, wins[:, 1:, :]


def apply_ssd_block(
    p: Dict, x, cfg,
    cache: Optional[LayerCache] = None,
    kernel_impl: str = "auto",
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, d = x.shape
    d_in, H, G, N = _dims(cfg)
    Pd = cfg.ssm_headdim
    h = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    z = matmul(h, p["wz"])
    xs = matmul(h, p["wx"])
    bc = matmul(h, p["wbc"])
    dt_raw = matmul(h, p["wdt"])
    z = constrain(z, "batch", "seq_full", "ssm_inner")
    xs = constrain(xs, "batch", "seq_full", "ssm_inner")

    new_cache = None
    decode = cache is not None and S == 1
    if decode:
        xs1, conv_x = _conv_step(xs[:, 0], cache.conv_x, p["conv_x"])
        bc1, conv_bc = _conv_step(bc[:, 0], cache.conv_bc, p["conv_bc"])
        xs, bc = xs1[:, None], bc1[:, None]
    else:
        if cache is not None:  # prefill: keep conv tails for decode
            K = p["conv_x"].shape[0]
            conv_x = xs[:, S - (K - 1):, :]
            conv_bc = bc[:, S - (K - 1):, :]
        xs = _causal_conv(xs, p["conv_x"])
        bc = _causal_conv(bc, p["conv_bc"])

    xs = jax.nn.silu(xs)
    bc = jax.nn.silu(bc)
    Bm = bc[..., : G * N].reshape(B, S, G, N)
    Cm = bc[..., G * N:].reshape(B, S, G, N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, Pd)
    xh = constrain(xh, "batch", "seq_full", "ssm_heads", None)

    if decode:
        y, state = ops.ssd_decode_step(xh, dtv, A, Bm, Cm, cache.state, p["D"])
        new_cache = LayerCache(kind="ssm", conv_x=conv_x, conv_bc=conv_bc,
                               state=state)
    else:
        y, state = ops.ssd(xh, dtv, A, Bm, Cm, p["D"],
                           chunk=cfg.ssm_chunk, impl=kernel_impl)
        if cache is not None:  # prefill
            new_cache = LayerCache(kind="ssm", conv_x=conv_x, conv_bc=conv_bc,
                                   state=state)

    y = y.reshape(B, S, d_in)
    # gated RMSNorm (mamba2's RMSNormGated)
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + cfg.norm_eps) * p["gnorm"].astype(jnp.float32)
    out = matmul(g.astype(x.dtype), p["wo"])
    return out, new_cache


