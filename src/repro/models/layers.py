"""Common model layers: pure-function init/apply over param dicts.

Convention: every ``init_*`` returns a pytree whose leaves are
``Leaf(value, axes)`` — the array plus its logical-axis names.  The model
splits this into a param tree and an axes tree (see ``split_leaves``); the
axes tree drives sharding (dist.sharding) and stays host-side.

All matmuls run in the param dtype (bf16 by default) with fp32 accumulation
via ``preferred_element_type``; norms and softmax in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import constrain
from ..kernels import ops
from .cache import LayerCache


@dataclasses.dataclass
class Leaf:
    value: Any
    axes: Tuple[Optional[str], ...]


# pytree node (axes static) so jax.eval_shape can trace init_model directly
jax.tree_util.register_dataclass(Leaf, data_fields=["value"], meta_fields=["axes"])


def is_leaf(x) -> bool:
    return isinstance(x, Leaf)


def split_leaves(tree):
    """Leaf tree -> (params tree, logical-axes tree)."""
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda l: l.axes, tree, is_leaf=is_leaf)
    return params, axes


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / np.sqrt(max(in_axis_size, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def matmul(x, w, ndim_contract: int = 1):
    """x @ w contracting the last ndim_contract dims of x with the first of w."""
    xc = tuple(range(x.ndim - ndim_contract, x.ndim))
    wc = tuple(range(ndim_contract))
    out = jax.lax.dot_general(
        x, w, ((xc, wc), ((), ())), preferred_element_type=jnp.float32
    )
    return out.astype(x.dtype)


def matmul_out(x, w, ndim_contract: int, *out_axes):
    """Second-projection matmul (contracting a TP-sharded dim).

    Two deliberate choices for the cross-device partial-sum reduction
    (§Perf log H-a/H-c):
      * the dot emits bf16 (each device's partial is still accumulated in
        fp32 inside the MXU and rounded once), so the TP all-reduce moves
        half the bytes of an fp32 reduction;
      * the output is sharding-constrained to the residual layout before
        any further op, which lets the TPU partitioner lower the reduction
        as reduce-scatter straight into the sequence-sharded layout (the
        CPU partitioner lacks RS and falls back to all-reduce — measured
        and documented in EXPERIMENTS.md).
    """
    xc = tuple(range(x.ndim - ndim_contract, x.ndim))
    wc = tuple(range(ndim_contract))
    out = jax.lax.dot_general(
        x, w, ((xc, wc), ((), ())), preferred_element_type=x.dtype
    )
    out = constrain(out, *out_axes)
    return out


# ---------------------------------------------------------------- norms
def init_norm(d: int, dtype, kind: str = "rms", axes=("embed2",)) -> Dict:
    p = {"scale": Leaf(jnp.ones((d,), dtype), axes)}
    if kind == "layer":
        p["bias"] = Leaf(jnp.zeros((d,), dtype), axes)
    return p


def apply_norm(p: Dict, x, kind: str = "rms", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x, positions, theta: float = 10000.0, mrope: bool = False):
    """x: (B, S, H, D); positions: (B, S) int32.

    ``mrope=True`` marks Qwen2-VL multimodal RoPE.  For the text-only
    backbone (the modality frontend is a stub per the assignment), all three
    M-RoPE position streams coincide, and M-RoPE reduces exactly to 1-D RoPE
    applied in interleaved sections — numerically identical here, kept as a
    flag for config fidelity (see DESIGN.md).
    """
    B, S, H, D = x.shape
    freqs = rope_freqs(D, theta)  # (D/2,)
    ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]  # (B,S,1,D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : D // 2], xf[..., D // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ embeddings
def init_embedding(key, vocab: int, d: int, dtype) -> Dict:
    emb = (jax.random.normal(key, (vocab, d), jnp.float32)
           / np.sqrt(d)).astype(dtype)
    return {"table": Leaf(emb, ("vocab", "embed"))}


def apply_embedding(p: Dict, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def apply_unembed(p: Dict, x):
    """Logits via the (tied or dedicated) (vocab, d) table."""
    return matmul(x, p["table"].T)


# -------------------------------------------------------------- attention
def init_attention(key, cfg) -> Dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": Leaf(_dense_init(ks[0], (d, H, Dh), d, dt), ("embed", "heads", "head_dim")),
        "wk": Leaf(_dense_init(ks[1], (d, Hkv, Dh), d, dt), ("embed", "kv_heads", "head_dim")),
        "wv": Leaf(_dense_init(ks[2], (d, Hkv, Dh), d, dt), ("embed", "kv_heads", "head_dim")),
        "wo": Leaf(_dense_init(ks[3], (H, Dh, d), H * Dh, dt), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = Leaf(jnp.zeros((H, Dh), dt), ("heads", "head_dim"))
        p["bk"] = Leaf(jnp.zeros((Hkv, Dh), dt), ("kv_heads", "head_dim"))
        p["bv"] = Leaf(jnp.zeros((Hkv, Dh), dt), ("kv_heads", "head_dim"))
    return p


def apply_attention(
    p: Dict,
    x,  # (B, S, d)
    cfg,
    positions,  # (B, S)
    window: Optional[int] = None,
    cache: Optional[Dict] = None,
    kernel_impl: str = "auto",
):
    """GQA attention; returns (out, new_cache).

    Cache kinds (see models.cache):
      full cache: {"kind":"full", "k","v": (B, Smax, Hkv, Dh), "pos"}
      ring cache: {"kind":"ring", "k","v": (B, W, Hkv, Dh), "pos"}
        — fixed-size sliding-window buffer; slot = pos % W; absolute key
          positions reconstructed from pos so masking stays exact.

    ``cache.pos`` may be scalar (every row at the same depth — the wave /
    train paths) or per-slot ``(B,)`` (the serving engine's slot-granular
    decode: each slot writes its own row and masks its own depth; per-slot
    cursors support single-token steps only — slot prefill runs unpadded
    at B=1 and is copied in via ``cache.write_prompt``).

    ``cache.start`` (optional, ``(B,)``) is each slot's first real row —
    left-padded wave prefills set it to the pad widths; real key position
    = row - start, and pad rows land at negative positions, which the
    mask rejects (this is what keeps shorter prompts in a padded wave
    from attending to their padding).
    """
    B, S, d = x.shape
    q = matmul(x, p["wq"])  # (B,S,H,Dh)
    k = matmul(x, p["wk"])
    v = matmul(x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    q = constrain(q, "batch", "seq_full", "act_heads", None)
    k = constrain(k, "batch", "seq_full", "kv_heads_act", None)

    new_cache = None
    start = cache.start if cache is not None else None

    def _offsets(pos, nrows):
        """(q_offset, kv_positions) for rows 0..nrows-1 at cursor ``pos``."""
        rows = jnp.arange(nrows, dtype=jnp.int32)[None, :]
        if start is None:
            if jnp.ndim(pos) == 0:
                return pos, None
            return pos, jnp.broadcast_to(rows, (B, nrows))
        return pos - start, rows - start[:, None]

    if cache is None:
        out = ops.attention(
            q, k, v, causal=cfg.causal, window=window, impl=kernel_impl
        )
    elif cache.kind == "full" and jnp.ndim(cache.pos) == 1:
        if S != 1:
            raise ValueError(
                "per-slot cache cursors support single-token decode only; "
                "prefill slots unpadded at B=1 and admit via write_prompt")
        pos = cache.pos  # (B,): #rows already cached per slot
        bidx = jnp.arange(B)
        ck = cache.k.at[bidx, pos].set(k[:, 0].astype(cache.k.dtype),
                                       mode="drop")
        cv = cache.v.at[bidx, pos].set(v[:, 0].astype(cache.v.dtype),
                                       mode="drop")
        ck = constrain(ck, "batch", "kv_seq", "kv_heads_act", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads_act", None)
        q_off, kv_pos = _offsets(pos, cache.k.shape[1])
        out = ops.attention(
            q, ck, cv, causal=True, window=window, q_offset=q_off,
            kv_positions=kv_pos, impl=kernel_impl,
        )
        new_cache = LayerCache(kind="full", k=ck, v=cv, pos=pos + 1,
                               start=start)
    elif cache.kind == "full":
        pos = cache.pos  # scalar int32: #tokens already cached
        ck = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, pos, 0, 0))
        ck = constrain(ck, "batch", "kv_seq", "kv_heads_act", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads_act", None)
        # slots beyond pos+S are zero/stale; causal mask with q_offset=pos
        # blocks every j > pos+S-1 so they are never read.
        q_off, kv_pos = _offsets(pos, cache.k.shape[1])
        out = ops.attention(
            q, ck, cv, causal=True, window=window, q_offset=q_off,
            kv_positions=kv_pos, impl=kernel_impl,
        )
        new_cache = LayerCache(kind="full", k=ck, v=cv, pos=pos + S,
                               start=start)
    elif cache.kind == "ring" and S > 1:
        # prefill: full-sequence windowed attention, then stash the last
        # min(W, S) keys/values into the ring buffer for decode.
        W = cache.k.shape[1]
        if start is None:
            q_off, kv_pos = 0, None
        else:
            q_off = -start
            kv_pos = jnp.arange(S, dtype=jnp.int32)[None, :] - start[:, None]
        out = ops.attention(
            q, k, v, causal=cfg.causal, window=window, q_offset=q_off,
            kv_positions=kv_pos, impl=kernel_impl
        )
        take = min(W, S)
        slots = (jnp.arange(S - take, S, dtype=jnp.int32)) % W
        ck = cache.k.at[:, slots].set(k[:, S - take:].astype(cache.k.dtype))
        cv = cache.v.at[:, slots].set(v[:, S - take:].astype(cache.v.dtype))
        new_cache = LayerCache(kind="ring", k=ck, v=cv, pos=cache.pos + S,
                               start=start)
    elif cache.kind == "ring":
        W = cache.k.shape[1]
        pos = cache.pos
        if jnp.ndim(pos) == 1:
            slot = pos % W
            bidx = jnp.arange(B)
            ck = cache.k.at[bidx, slot].set(k[:, 0].astype(cache.k.dtype))
            cv = cache.v.at[bidx, slot].set(v[:, 0].astype(cache.v.dtype))
            slots = jnp.arange(W, dtype=jnp.int32)[None, :]
            rows = pos[:, None] - ((pos[:, None] - slots) % W)
        else:
            slot = pos % W
            ck = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
            # slot s holds absolute position: largest p <= pos, p % W == s
            slots = jnp.arange(W, dtype=jnp.int32)
            rows = pos - ((pos - slots) % W)  # in (pos-W, pos]
        ck = constrain(ck, "batch", "kv_seq", "kv_heads_act", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads_act", None)
        q_off = pos if start is None else pos - start
        kv_pos = rows if start is None else (
            (rows if rows.ndim == 2 else rows[None, :]) - start[:, None])
        out = ops.attention(
            q, ck, cv, causal=True, window=window, q_offset=q_off,
            kv_positions=kv_pos, impl=kernel_impl,
        )
        new_cache = LayerCache(kind="ring", k=ck, v=cv, pos=pos + 1,
                               start=start)
    else:
        raise ValueError(cache.kind)

    out = constrain(out, "batch", "seq_full", "act_heads", None)
    y = matmul_out(out, p["wo"], 2, "batch", "seq", None)
    return y, new_cache


# -------------------------------------------------------------------- MLP
def init_mlp(key, cfg, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        return {
            "wi_gate": Leaf(_dense_init(ks[0], (d, f), d, dt), ("embed", "ffn")),
            "wi_up": Leaf(_dense_init(ks[1], (d, f), d, dt), ("embed", "ffn")),
            "wo": Leaf(_dense_init(ks[2], (f, d), f, dt), ("ffn", "embed")),
        }
    return {
        "wi": Leaf(_dense_init(ks[0], (d, f), d, dt), ("embed", "ffn")),
        "bi": Leaf(jnp.zeros((f,), dt), ("ffn",)),
        "wo": Leaf(_dense_init(ks[2], (f, d), f, dt), ("ffn", "embed")),
        "bo": Leaf(jnp.zeros((d,), dt), ("embed2",)),
    }


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def apply_mlp(p: Dict, x, cfg):
    if "wi_gate" in p:
        g = matmul(x, p["wi_gate"])
        u = matmul(x, p["wi_up"])
        h = _act(cfg.act, g) * u
        h = constrain(h, "batch", "seq_full", "act_ffn")
        return matmul_out(h, p["wo"], 1, "batch", "seq", None)
    h = _act(cfg.act, matmul(x, p["wi"]) + p["bi"])
    h = constrain(h, "batch", "seq_full", "act_ffn")
    return matmul_out(h, p["wo"], 1, "batch", "seq", None) + p["bo"]
