"""Decode-cache construction per block kind.

``LayerCache`` is a pytree dataclass whose ``kind`` is static metadata:
  full  — (B, max_len, Hkv, Dh) K/V, for full-attention layers
  ring  — (B, W, Hkv, Dh) sliding-window ring buffer (SWA / local attention)
  ssm   — Mamba-2 conv tail + (B, H, P, N) SSD state
  rglru — conv tail + (B, w) recurrent state

Fixed-window layers get ring buffers whenever the window is smaller than
the nominal cache length — this is what bounds the ``long_500k`` working
set for the sub-quadratic architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class LayerCache:
    kind: str  # static
    k: Any = None
    v: Any = None
    pos: Any = None
    conv_x: Any = None
    conv_bc: Any = None
    state: Any = None
    conv: Any = None
    h: Any = None


jax.tree_util.register_dataclass(
    LayerCache,
    data_fields=["k", "v", "pos", "conv_x", "conv_bc", "state", "conv", "h"],
    meta_fields=["kind"],
)


def init_layer_cache(kind: str, cfg, batch: int, max_len: int, dtype) -> LayerCache:
    if kind == "ssd":
        from .ssm import _dims

        d_in, H, G, N = _dims(cfg)
        K = cfg.ssm_conv
        return LayerCache(
            kind="ssm",
            conv_x=jnp.zeros((batch, K - 1, d_in), dtype),
            conv_bc=jnp.zeros((batch, K - 1, 2 * G * N), dtype),
            state=jnp.zeros((batch, H, cfg.ssm_headdim, N), jnp.float32),
        )
    if kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return LayerCache(
            kind="rglru",
            conv=jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
            h=jnp.zeros((batch, w), jnp.float32),
        )
    if kind in ("attn", "moe"):
        window = cfg.window
    elif kind == "local_attn":
        window = cfg.local_window
    else:
        raise ValueError(kind)
    Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
    if window is not None and window < max_len:
        return LayerCache(
            kind="ring",
            k=jnp.zeros((batch, window, Hkv, Dh), dtype),
            v=jnp.zeros((batch, window, Hkv, Dh), dtype),
            pos=jnp.zeros((), jnp.int32),
        )
    return LayerCache(
        kind="full",
        k=jnp.zeros((batch, max_len, Hkv, Dh), dtype),
        v=jnp.zeros((batch, max_len, Hkv, Dh), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def init_caches(cfg, batch: int, max_len: int, dtype=None) -> List[LayerCache]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    return [
        init_layer_cache(kind, cfg, batch, max_len, dtype)
        for kind in cfg.pattern_for_depth()
    ]


def cache_logical_axes(cache: LayerCache) -> LayerCache:
    """Logical sharding axes per leaf (same treedef as the cache)."""
    kind = cache.kind
    if kind in ("full", "ring"):
        return LayerCache(
            kind=kind,
            k=("batch", "kv_seq", "kv_heads_act", None),
            v=("batch", "kv_seq", "kv_heads_act", None),
            pos=(),
        )
    if kind == "ssm":
        return LayerCache(
            kind=kind,
            conv_x=("batch", None, "ssm_inner"),
            conv_bc=("batch", None, None),
            state=("batch", "ssm_heads", None, None),
        )
    if kind == "rglru":
        return LayerCache(
            kind=kind,
            conv=("batch", None, "lru"),
            h=("batch", "lru"),
        )
    raise ValueError(kind)
