"""Decode-cache construction per block kind.

``LayerCache`` is a pytree dataclass whose ``kind`` is static metadata:
  full  — (B, max_len, Hkv, Dh) K/V, for full-attention layers
  ring  — (B, W, Hkv, Dh) sliding-window ring buffer (SWA / local attention)
  ssm   — Mamba-2 conv tail + (B, H, P, N) SSD state
  rglru — conv tail + (B, w) recurrent state

Fixed-window layers get ring buffers whenever the window is smaller than
the nominal cache length — this is what bounds the ``long_500k`` working
set for the sub-quadratic architectures.

Serving extensions (the slot-granular continuous-batching engine):

* ``pos`` may be a per-slot ``(B,)`` vector instead of a scalar
  (``init_caches(..., per_slot_pos=True)``): each batch slot keeps its own
  write cursor, so requests admitted mid-decode sit at different depths in
  one persistent cache.
* ``start`` (attention kinds only) is an optional ``(B,)`` row offset of
  each slot's first *real* token — left-padded wave prefills set it to the
  pad widths so the attention mask can reject pad keys (real position =
  cache row - start; negative = invalid).
* ``reset_slot`` / ``write_prompt`` are the per-slot lifecycle: a slot is
  recycled in place (no realloc) when its request completes, and a new
  request's single-sequence prefill cache is copied into the freed slot.
* ``stack_caches`` / ``unstack_caches`` convert between the per-layer list
  and the pre-stacked ``LayerCache`` (leading layer dim) that
  ``models.model.forward`` scans in place — the production serve layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class LayerCache:
    kind: str  # static
    k: Any = None
    v: Any = None
    pos: Any = None
    conv_x: Any = None
    conv_bc: Any = None
    state: Any = None
    conv: Any = None
    h: Any = None
    start: Any = None  # (B,) row of each slot's first real token (attn kinds)


jax.tree_util.register_dataclass(
    LayerCache,
    data_fields=["k", "v", "pos", "conv_x", "conv_bc", "state", "conv", "h",
                 "start"],
    meta_fields=["kind"],
)


def init_layer_cache(kind: str, cfg, batch: int, max_len: int, dtype,
                     per_slot_pos: bool = False) -> LayerCache:
    if kind == "ssd":
        from .ssm import _dims

        d_in, H, G, N = _dims(cfg)
        K = cfg.ssm_conv
        return LayerCache(
            kind="ssm",
            conv_x=jnp.zeros((batch, K - 1, d_in), dtype),
            conv_bc=jnp.zeros((batch, K - 1, 2 * G * N), dtype),
            state=jnp.zeros((batch, H, cfg.ssm_headdim, N), jnp.float32),
        )
    if kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return LayerCache(
            kind="rglru",
            conv=jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
            h=jnp.zeros((batch, w), jnp.float32),
        )
    if kind in ("attn", "moe"):
        window = cfg.window
    elif kind == "local_attn":
        window = cfg.local_window
    else:
        raise ValueError(kind)
    Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
    pos0 = (jnp.zeros((batch,), jnp.int32) if per_slot_pos
            else jnp.zeros((), jnp.int32))
    if window is not None and window < max_len:
        return LayerCache(
            kind="ring",
            k=jnp.zeros((batch, window, Hkv, Dh), dtype),
            v=jnp.zeros((batch, window, Hkv, Dh), dtype),
            pos=pos0,
        )
    return LayerCache(
        kind="full",
        k=jnp.zeros((batch, max_len, Hkv, Dh), dtype),
        v=jnp.zeros((batch, max_len, Hkv, Dh), dtype),
        pos=pos0,
    )


def init_caches(cfg, batch: int, max_len: int, dtype=None,
                per_slot_pos: bool = False) -> List[LayerCache]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    return [
        init_layer_cache(kind, cfg, batch, max_len, dtype,
                         per_slot_pos=per_slot_pos)
        for kind in cfg.pattern_for_depth()
    ]


# ------------------------------------------------- slot lifecycle (serving)
Caches = Union[LayerCache, List[LayerCache]]

_STATE_FIELDS = ("k", "v", "conv_x", "conv_bc", "state", "conv", "h")


def stack_caches(caches: Sequence[LayerCache]) -> LayerCache:
    """Per-layer list -> one LayerCache with a leading layer dim.

    Only valid for homogeneous stacks (every layer the same kind/shape);
    the result is what ``model.forward`` accepts pre-stacked and scans
    with in-place updates (no per-step stack/unstack copies).
    """
    kinds = {c.kind for c in caches}
    if len(kinds) != 1:
        raise ValueError(f"cannot stack heterogeneous cache kinds {kinds}")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


def unstack_caches(stacked: LayerCache, num_layers: int) -> List[LayerCache]:
    """Inverse of ``stack_caches`` (copies; diagnostic/test use)."""
    return [jax.tree.map(lambda s: s[i], stacked) for i in range(num_layers)]


def _layer_reset_slot(cache: LayerCache, slot) -> LayerCache:
    """Zero batch entry ``slot`` of one layer's cache (pos/start included)."""
    upd = {}
    for f in _STATE_FIELDS:
        a = getattr(cache, f)
        if a is not None:
            upd[f] = a.at[slot].set(jnp.zeros((), a.dtype), mode="drop")
    for f in ("pos", "start"):
        a = getattr(cache, f)
        if a is not None:
            upd[f] = (a.at[slot].set(0, mode="drop") if a.ndim == 1
                      else jnp.zeros_like(a))
    return dataclasses.replace(cache, **upd)


def _layer_write_prompt(cache: LayerCache, slot,
                        prefill: LayerCache) -> LayerCache:
    """Copy a single-sequence (B=1) prefill cache into batch slot ``slot``.

    Overwrites the slot's *entire* state (K/V rows, conv tails, recurrent
    state, cursor), so admission into a dirty slot needs no separate
    reset.  ``prefill.pos`` may be scalar (the B=1 prefill path) or (1,).
    """
    if cache.kind != prefill.kind:
        raise ValueError(f"cache kind mismatch: {cache.kind} vs {prefill.kind}")
    upd = {}
    for f in _STATE_FIELDS:
        a, p = getattr(cache, f), getattr(prefill, f)
        if a is not None:
            upd[f] = a.at[slot].set(p[0].astype(a.dtype), mode="drop")
    for f in ("pos", "start"):
        a, p = getattr(cache, f), getattr(prefill, f)
        if a is None:
            continue
        if a.ndim == 0:
            raise ValueError(
                "write_prompt needs per-slot cursors; build the engine cache "
                "with init_caches(..., per_slot_pos=True)")
        src = jnp.zeros((), a.dtype) if p is None else (
            p if jnp.ndim(p) == 0 else p[0])
        upd[f] = a.at[slot].set(src.astype(a.dtype), mode="drop")
    return dataclasses.replace(cache, **upd)


def reset_slot(caches: Caches, slot) -> Caches:
    """Zero one batch slot across every layer (list or stacked caches)."""
    slot = jnp.asarray(slot, jnp.int32)
    if isinstance(caches, LayerCache):
        return jax.vmap(lambda c: _layer_reset_slot(c, slot))(caches)
    return [_layer_reset_slot(c, slot) for c in caches]


def write_prompt(caches: Caches, slot, prefill: Caches) -> Caches:
    """Admit a prefilled request into batch slot ``slot``.

    ``prefill`` is the cache a B=1 unpadded prefill produced (list for
    unrolled stacks, stacked LayerCache for scanned homogeneous stacks —
    matching ``caches``); its whole per-slot state is copied in, replacing
    whatever the freed slot held.
    """
    slot = jnp.asarray(slot, jnp.int32)
    if isinstance(caches, LayerCache):
        if not isinstance(prefill, LayerCache):
            prefill = stack_caches(prefill)
        return jax.vmap(lambda c, p: _layer_write_prompt(c, slot, p))(
            caches, prefill)
    return [_layer_write_prompt(c, slot, p) for c, p in zip(caches, prefill)]


def cache_logical_axes(cache: LayerCache) -> LayerCache:
    """Logical sharding axes per leaf (same treedef as the cache)."""
    kind = cache.kind
    if kind in ("full", "ring"):
        return LayerCache(
            kind=kind,
            k=("batch", "kv_seq", "kv_heads_act", None),
            v=("batch", "kv_seq", "kv_heads_act", None),
            pos=(),
        )
    if kind == "ssm":
        return LayerCache(
            kind=kind,
            conv_x=("batch", None, "ssm_inner"),
            conv_bc=("batch", None, None),
            state=("batch", "ssm_heads", None, None),
        )
    if kind == "rglru":
        return LayerCache(
            kind=kind,
            conv=("batch", None, "lru"),
            h=("batch", "lru"),
        )
    raise ValueError(kind)
