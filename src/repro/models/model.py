"""Unified LM: builds any assigned architecture from its ModelConfig.

Block kinds (cycled through ``cfg.block_pattern``):
  attn       — pre-norm GQA attention + gated/plain MLP
  local_attn — same with ``cfg.local_window`` sliding window
  moe        — attention + top-k MoE FFN (+ optional Arctic dense residual)
  ssd        — Mamba-2 mixer block (no MLP)
  rglru      — Griffin recurrent block + MLP

Homogeneous stacks run under ``lax.scan`` over stacked per-layer params
(compile time O(1) in depth — essential for the 80-layer dry-runs);
heterogeneous patterns (RecurrentGemma's 26-layer 1:2 hybrid) unroll.
``cfg.remat`` wraps each block in ``jax.checkpoint``: the only live
activations between layers are the (batch-, sequence-sharded) residuals.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import layers as L
from .cache import LayerCache, init_caches
from .moe import apply_moe, init_moe
from .rglru import apply_rglru_block, init_rglru_block
from .ssm import apply_ssd_block, init_ssd_block


# ------------------------------------------------------------------ blocks
def init_block(key, kind: str, cfg) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    if kind in ("attn", "local_attn"):
        return {
            "norm1": L.init_norm(cfg.d_model, dt, cfg.norm),
            "attn": L.init_attention(ks[0], cfg),
            "norm2": L.init_norm(cfg.d_model, dt, cfg.norm),
            "mlp": L.init_mlp(ks[1], cfg),
        }
    if kind == "moe":
        p = {
            "norm1": L.init_norm(cfg.d_model, dt, cfg.norm),
            "attn": L.init_attention(ks[0], cfg),
            "norm2": L.init_norm(cfg.d_model, dt, cfg.norm),
            "moe": init_moe(ks[1], cfg),
        }
        if cfg.dense_residual_ff:
            p["mlp"] = L.init_mlp(ks[2], cfg, d_ff=cfg.dense_residual_ff)
        return p
    if kind == "ssd":
        return {"ssd": init_ssd_block(ks[0], cfg)}
    if kind == "rglru":
        return {
            "rec": init_rglru_block(ks[0], cfg),
            "norm2": L.init_norm(cfg.d_model, dt, cfg.norm),
            "mlp": L.init_mlp(ks[1], cfg),
        }
    raise ValueError(kind)


def apply_block(
    p: Dict, kind: str, x, cfg, positions,
    cache: Optional[LayerCache] = None,
) -> Tuple[jax.Array, Optional[LayerCache], Tuple]:
    """Returns (x', new_cache, (moe_lb, moe_z))."""
    x = constrain(x, "batch", "seq", None)
    zero = jnp.zeros((), jnp.float32)
    aux = (zero, zero)
    if kind in ("attn", "local_attn", "moe"):
        window = cfg.local_window if kind == "local_attn" else cfg.window
        h = L.apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
        a, new_cache = L.apply_attention(
            p["attn"], h, cfg, positions, window=window, cache=cache,
            kernel_impl=cfg.kernel_impl,
        )
        x = x + a
        h = L.apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if kind == "moe":
            m, metrics = apply_moe(p["moe"], h, cfg, impl=cfg.moe_impl)
            aux = (metrics["moe_lb_loss"], metrics["moe_z_loss"])
            if "mlp" in p:  # Arctic: dense MLP residual in parallel
                m = m + L.apply_mlp(p["mlp"], h, cfg)
            x = x + m
        else:
            x = x + L.apply_mlp(p["mlp"], h, cfg)
    elif kind == "ssd":
        a, new_cache = apply_ssd_block(
            p["ssd"], x, cfg, cache=cache, kernel_impl=cfg.kernel_impl)
        x = x + a
    elif kind == "rglru":
        a, new_cache = apply_rglru_block(p["rec"], x, cfg, cache=cache)
        x = x + a
        h = L.apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        x = x + L.apply_mlp(p["mlp"], h, cfg)
    else:
        raise ValueError(kind)
    x = constrain(x, "batch", "seq", None)
    return x, new_cache, aux


def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(cfg.remat)


# ------------------------------------------------------------------- model
def init_model(key, cfg):
    """Returns a Leaf tree (arrays + logical axes)."""
    ks = jax.random.split(key, cfg.num_layers + 3)
    tree: Dict[str, Any] = {
        "embed": L.init_embedding(ks[0], cfg.vocab_size, cfg.d_model, jnp.dtype(cfg.dtype)),
        "final_norm": L.init_norm(cfg.d_model, jnp.dtype(cfg.dtype), cfg.norm),
    }
    if not cfg.tie_embeddings:
        tree["head"] = L.init_embedding(ks[1], cfg.vocab_size, cfg.d_model,
                                        jnp.dtype(cfg.dtype))
    pattern = cfg.pattern_for_depth()
    if cfg.scan_layers and len(set(pattern)) == 1:
        per_layer = [init_block(ks[3 + i], pattern[0], cfg)
                     for i in range(cfg.num_layers)]
        stacked = jax.tree.map(
            lambda *ls: L.Leaf(jnp.stack([l.value for l in ls]),
                               ("layers",) + ls[0].axes),
            *per_layer, is_leaf=L.is_leaf)
        tree["blocks_scanned"] = stacked
    else:
        tree["blocks"] = [init_block(ks[3 + i], pattern[i], cfg)
                          for i in range(cfg.num_layers)]
    return tree


def model_spec(cfg):
    """(params_struct, axes) via eval_shape — no allocation (dry-run path)."""
    leaf_tree = jax.eval_shape(functools.partial(init_model, cfg=cfg),
                               jax.random.PRNGKey(0))
    return L.split_leaves(leaf_tree)


def forward(
    params: Dict, cfg,
    tokens: Optional[jax.Array] = None,   # (B, S) int32
    embeds: Optional[jax.Array] = None,   # (B, S, d) modality-frontend stub
    caches: Optional[List[LayerCache]] = None,
    pos=0,  # absolute position of the first input token: scalar or (B,)
    last_token_only: bool = False,  # unembed only the final position
) -> Tuple[jax.Array, Optional[List[LayerCache]], Dict]:
    """Returns (logits, new_caches, aux)."""
    if embeds is not None:
        h = embeds.astype(jnp.dtype(cfg.dtype))
        B, S = embeds.shape[:2]
    else:
        h = L.apply_embedding(params["embed"], tokens)
        B, S = tokens.shape
    h = constrain(h, "batch", "seq", None)
    pos_arr = jnp.asarray(pos, jnp.int32)
    if pos_arr.ndim == 1:  # per-slot depths (serving engine)
        positions = pos_arr[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    else:
        positions = pos_arr + jnp.arange(S, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (B, S))

    pattern = cfg.pattern_for_depth()
    lb = jnp.zeros((), jnp.float32)
    zl = jnp.zeros((), jnp.float32)
    new_caches: Optional[List[LayerCache]] = None

    if "blocks_scanned" in params:
        kind = pattern[0]
        block = functools.partial(apply_block, kind=kind, cfg=cfg)

        if caches is None:
            def body(carry, layer_params):
                x, lb_c, zl_c = carry
                x, _, (lb_i, zl_i) = _maybe_remat(
                    lambda p, xx: block(p, x=xx, positions=positions), cfg
                )(layer_params, x)
                return (x, lb_c + lb_i, zl_c + zl_i), None

            (h, lb, zl), _ = jax.lax.scan(
                body, (h, lb, zl), params["blocks_scanned"])
        else:
            # caches ride in the CARRY (not xs->ys): the per-layer update is
            # an in-place dynamic_update_index into the donated stacked
            # buffer, so decode holds ONE copy of the KV cache instead of
            # scan double-buffering input and output stacks (§Perf log).
            # Callers may pass the caches pre-stacked (LayerCache with a
            # leading layer dim) — the production serve path — and then get
            # the stacked cache back without any unstack copies.
            pre_stacked = isinstance(caches, LayerCache)
            stacked_caches = caches if pre_stacked else jax.tree.map(
                lambda *xs: jnp.stack(xs), *caches)

            def body(carry, layer_params):
                x, lb_c, zl_c, caches_st, idx = carry
                cache_i = jax.tree.map(
                    lambda s: jax.lax.dynamic_index_in_dim(
                        s, idx, 0, keepdims=False), caches_st)
                x, new_c, (lb_i, zl_i) = _maybe_remat(
                    lambda p, xx, cc: block(p, x=xx, positions=positions,
                                            cache=cc), cfg
                )(layer_params, x, cache_i)
                caches_st = jax.tree.map(
                    lambda s, n: jax.lax.dynamic_update_index_in_dim(
                        s, n, idx, 0), caches_st, new_c)
                return (x, lb_c + lb_i, zl_c + zl_i, caches_st, idx + 1), None

            (h, lb, zl, new_stacked, _), _ = jax.lax.scan(
                body, (h, lb, zl, stacked_caches, jnp.int32(0)),
                params["blocks_scanned"])
            if pre_stacked:
                new_caches = new_stacked
            else:
                new_caches = [jax.tree.map(lambda s: s[i], new_stacked)
                              for i in range(cfg.num_layers)]
    else:
        new_caches = [] if caches is not None else None
        for i, bp in enumerate(params["blocks"]):
            cache_i = caches[i] if caches is not None else None
            h, new_c, (lb_i, zl_i) = _maybe_remat(
                lambda p, xx, cc: apply_block(p, pattern[i], xx, cfg,
                                              positions, cache=cc), cfg
            )(bp, h, cache_i)
            lb, zl = lb + lb_i, zl + zl_i
            if caches is not None:
                new_caches.append(new_c)

    if last_token_only:
        # prefill/serving: project only the final position (a 32k-token
        # prefill does not need 32k rows of 152k-vocab logits — §Perf log)
        h = h[:, -1:, :]
    h = L.apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = L.apply_unembed(head, h)
    logits = constrain(logits, "batch", "seq", "vocab_out")
    aux = {"moe_lb_loss": lb, "moe_z_loss": zl}
    return logits, new_caches, aux
