"""Mixture-of-Experts layer: top-k token-choice routing (Mixtral/Arctic).

Two execution paths:

* ``dense`` — every expert computed for every token, gate-weighted (exact,
  O(E/k) compute overhead).  Used for tiny smoke configs and as a fallback.

* ``a2a`` — production path: expert parallelism over the ``data`` mesh axis
  with explicit ``shard_map`` dispatch/combine through
  ``dist.collectives.TokenA2APlan``, and tensor parallelism over ``model``
  inside each expert.  Pods form independent EP groups (no cross-pod
  all-to-all: DCN stays out of the token path).

The a2a path runs in one of two expert-parallel modes (``cfg.ep_mode``,
overridable per call):

``ep_mode="replicated"``
    Tokens are replicated over ``model`` inside the MoE region; every model
    plane performs the identical dispatch all-to-all.  Collectives per
    layer: dispatch a2a (x |model| planes), expert-TP psum, combine a2a
    (x |model| planes).  Simple, but the dispatch volume is duplicated per
    model plane.

``ep_mode="sp"``
    SP-aware expert parallelism: the sequence axis stays sharded over
    ``model`` inside the MoE region (logical axis ``seq_moe``), so each
    model plane routes and all-to-alls only its own sequence shard —
    per-plane a2a volume drops by |model|.  The received token rows are
    then all-gathered over ``model`` so the f-sliced expert TP psum sums
    matching rows, each plane slices its own rows back out, and the
    combine a2a again moves only the plane's shard.  Extra collective: one
    all-gather of the dispatched rows over ``model``; removed collectives:
    the seq all-gather into the MoE region and the re-scatter out of it
    (the residual stream is already sequence-parallel over ``model``).
    Falls back to ``replicated`` when the sequence length does not divide
    the ``model`` axis (same divisibility-fallback contract as
    ``dist.sharding``).  Capacity drops are deterministic per plane, so
    under pressure the two modes may drop different tokens; with adequate
    ``moe_capacity_factor`` they agree to reduction-order tolerance (see
    ``test_moe_sp_matches_replicated``).

Virtual sub-experts: the production mesh fixes |data| = 16; when ``E`` does
not divide it (Mixtral's 8 experts), each expert is split into
``sub = lcm(E,16)/E`` f-slices ("virtual sub-experts") so the expert shard
dim always divides the mesh axis.  A token routed to expert e sends its
activation to all ``sub`` slices and sums their partial outputs —
numerically identical to the unsplit expert (Megatron-style intra-expert TP
expressed as extra expert shards).  Cost: dispatch volume x``sub`` for such
archs; recorded in EXPERIMENTS.md.

Router: softmax over E in fp32, top-k, renormalized gates (Mixtral style),
load-balance aux loss (Switch) + router z-loss.  Capacity-factor dropping
is deterministic in token order.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..dist import collectives as CC
from ..dist.sharding import active_rules, constrain
from .layers import Leaf, _act, _dense_init

EP_MODES = ("replicated", "sp")


def _sub_factor(E: int, ndata: int) -> int:
    return math.lcm(E, ndata) // E


def virtual_experts(num_experts: int, d_ff: int) -> Tuple[int, int, int]:
    """The stored expert layout ``(E_v, f_v, sub)`` of ``init_moe``."""
    sub = _sub_factor(num_experts, 16)
    if d_ff % sub:
        sub = 1
    return num_experts * sub, d_ff // sub, sub


def init_moe(key, cfg) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    # store at the finest virtualization the production mesh needs (16);
    # the layout is transparent to smaller meshes (expert dim just divides).
    E_v, f_v, _ = virtual_experts(E, f)
    return {
        "router": Leaf(_dense_init(ks[0], (d, E), d, jnp.float32), (None, None)),
        "w_gate": Leaf(_dense_init(ks[1], (E_v, d, f_v), d, dt),
                       ("expert", "expert_embed", "expert_ffn")),
        "w_up": Leaf(_dense_init(ks[2], (E_v, d, f_v), d, dt),
                     ("expert", "expert_embed", "expert_ffn")),
        "w_down": Leaf(_dense_init(ks[3], (E_v, f_v, d), f, dt),
                       ("expert", "expert_ffn", "expert_embed")),
    }


def _router(x2d, wr, E: int, k: int):
    """fp32 routing -> (gates (N,k), top_idx (N,k), loss pieces).

    Loss pieces (load (E,), importance (E,), n, z_sum) are SUMS so callers
    can psum them across shards and form the exact global losses (a mean of
    per-shard losses is not the global loss — caught by
    test_moe_a2a_matches_dense)."""
    logits = jnp.einsum("nd,de->ne", x2d.astype(jnp.float32),
                        wr.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, k)
    gates = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (N, k, E)
    load = onehot.sum(axis=(0, 1))
    importance = probs.sum(axis=0)
    n = jnp.float32(probs.shape[0])
    z_sum = jnp.sum(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, top_idx, (load, importance, n, z_sum)


def _form_losses(pieces, E: int, k: int):
    load, importance, n, z_sum = pieces
    lb = E * jnp.sum((load / (n * k)) * (importance / n))
    return lb, z_sum / n


def _ffn(blocks, wg, wu, wd, act: str):
    """blocks: (E_loc, C, d) -> (E_loc, C, d) partial outputs (f-sliced)."""
    g = jnp.einsum("ecd,edf->ecf", blocks, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", blocks, wu,
                   preferred_element_type=jnp.float32)
    h = (_act(act, g) * u).astype(blocks.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wd,
                      preferred_element_type=jnp.float32).astype(blocks.dtype)


def apply_moe(p: Dict, x, cfg, impl: str = "auto",
              ep_mode: Optional[str] = None) -> Tuple[jax.Array, Dict]:
    """x: (B, S, d) -> (y, metrics).

    ``ep_mode`` overrides ``cfg.ep_mode`` for the a2a path (see module
    docstring); ``None`` reads the config.
    """
    rules = active_rules()
    if impl == "auto":
        use_a2a = (
            rules is not None
            and "data" in rules.mesh.shape
            and "model" in rules.mesh.shape
            and p["w_gate"].shape[0] % rules.mesh.shape["data"] == 0
            and p["w_gate"].shape[2] % rules.mesh.shape["model"] == 0
        )
        impl = "a2a" if use_a2a else "dense"
    if impl == "a2a":
        mode = ep_mode or getattr(cfg, "ep_mode", "replicated")
        if mode not in EP_MODES:
            raise ValueError(
                f"unknown ep_mode {mode!r}; known: {EP_MODES}")
        return _moe_a2a(p, x, cfg, rules, mode)
    return _moe_dense(p, x, cfg)


# ------------------------------------------------------------- dense path
def _moe_dense(p: Dict, x, cfg) -> Tuple[jax.Array, Dict]:
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    E_v = p["w_gate"].shape[0]
    sub = E_v // E
    x2 = x.reshape(B * S, d)
    gates, top_idx, pieces = _router(x2, p["router"], E, k)
    lb, z = _form_losses(pieces, E, k)

    g = jnp.einsum("nd,vdf->nvf", x2, p["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("nd,vdf->nvf", x2, p["w_up"],
                   preferred_element_type=jnp.float32)
    h = (_act(cfg.act, g) * u).astype(x.dtype)
    y_v = jnp.einsum("nvf,vfd->nvd", h, p["w_down"],
                     preferred_element_type=jnp.float32)
    y_e = y_v.reshape(B * S, E, sub, d).sum(axis=2)  # (N, E, d) fp32

    sel = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (N, k, E)
    w = (sel * gates[..., None]).sum(axis=1)  # (N, E)
    y = jnp.einsum("ned,ne->nd", y_e, w)
    return y.reshape(B, S, d).astype(x.dtype), {"moe_lb_loss": lb, "moe_z_loss": z}


# --------------------------------------------------------------- a2a path
def _local_dim(mesh, spec_entry) -> int:
    if spec_entry is None:
        return 1
    axes = (spec_entry,) if isinstance(spec_entry, str) else spec_entry
    return int(np.prod([mesh.shape[a] for a in axes]))


def _spec_uses(spec_entry, axis: str) -> bool:
    if spec_entry is None:
        return False
    axes = (spec_entry,) if isinstance(spec_entry, str) else spec_entry
    return axis in axes


def _moe_a2a(p: Dict, x, cfg, rules, ep_mode: str) -> Tuple[jax.Array, Dict]:
    mesh = rules.mesh
    all_axes = tuple(mesh.shape.keys())
    ndata = mesh.shape["data"]
    nmodel = mesh.shape["model"]
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    E_v, _, f_v = p["w_gate"].shape
    sub = E_v // E
    E_loc = E_v // ndata  # virtual experts per data-rank
    factor = cfg.moe_capacity_factor

    # Token layout inside the MoE region.  replicated: tokens REPLICATED
    # over `model` (the expert-TP psum sums f-slice partials across model
    # ranks, which needs every model rank to hold the SAME rows — with seq
    # sharded and no gather, the psum would mix different tokens' partials,
    # caught by test_moe_a2a_matches_dense).  sp: seq stays sharded over
    # `model` (logical axis "seq_moe") and the rows are all-gathered over
    # `model` AFTER dispatch, so each plane's a2a moves 1/|model| of the
    # volume.  Divisibility fallback: if S doesn't shard over model the
    # sp request degrades to replicated.
    seq_axis = "seq_moe" if ep_mode == "sp" else "seq_full"
    x_spec = rules.spec_for(("batch", seq_axis, None), x.shape)
    sp = ep_mode == "sp" and _spec_uses(x_spec[1], "model")
    if not sp:
        seq_axis = "seq_full"
        x_spec = rules.spec_for(("batch", seq_axis, None), x.shape)
    x = constrain(x, "batch", seq_axis, None)
    b_loc = B // _local_dim(mesh, x_spec[0])
    s_loc = S // _local_dim(mesh, x_spec[1])
    n_loc = b_loc * s_loc
    sends = n_loc * k * sub
    cap = CC.dispatch_capacity(sends, ndata, factor)
    plan = CC.TokenA2APlan(axis="data", ndev=ndata, cap=cap)

    def moe_local(xb, wr_l, wg_l, wu_l, wd_l):
        x2 = xb.reshape(n_loc, d)
        gates, top_idx, pieces = _router(x2, wr_l, E, k)
        # exact global losses: psum the sufficient statistics, then form
        # (replicated mode double-counts tokens over `model`; the ratios
        # cancel the overcount.  sp mode sums each token once.)
        pieces = jax.lax.psum(pieces, all_axes)
        lb, z = _form_losses(pieces, E, k)

        # expand to virtual sub-expert sends: (n, k, sub) -> flat M
        ev = top_idx[:, :, None] * sub + jnp.arange(sub)[None, None, :]
        ev = ev.reshape(-1)                       # (M,) virtual expert ids
        gts = jnp.repeat(gates.reshape(-1), sub)  # (M,)
        tok = jnp.repeat(jnp.arange(n_loc), k * sub)  # (M,) source token

        dest = ev // E_loc          # destination data-rank
        ev_local = ev % E_loc       # expert index on that rank
        slot, keep = plan.route(dest)

        # dispatch all-to-all over the data axis (within-pod EP groups)
        rx = plan.dispatch(dest, slot, x2[tok])            # (ndata*cap, d)
        re = plan.dispatch(dest, slot, ev_local, fill=-1)  # (ndata*cap,)
        if sp:
            # each plane dispatched only its own sequence shard; gather the
            # planes' rows so the f-sliced expert-TP psum below sums
            # partials of the SAME rows on every model rank
            rx = jax.lax.all_gather(rx, "model").reshape(-1, d)
            re = jax.lax.all_gather(re, "model").reshape(-1)
        R = re.shape[0]
        valid = re >= 0

        if E_loc == 1:
            part = _ffn(rx[None], wg_l, wu_l, wd_l, cfg.act)
            part = jax.lax.psum(part, "model")  # sum f_v TP partials
            out_rows = part[0] * valid[:, None].astype(part.dtype)
        else:
            cap_e = max(8, int(math.ceil(factor * R / E_loc / 8.0) * 8))
            oh = jax.nn.one_hot(re, E_loc, dtype=jnp.int32) * valid[:, None]
            pos = jnp.cumsum(oh, axis=0) - oh
            pos = (pos * oh).sum(-1)
            ok = valid & (pos < cap_e)
            pos_c = jnp.where(ok, pos, cap_e)
            e_safe = jnp.clip(re, 0, E_loc - 1)
            buf = jnp.zeros((E_loc, cap_e + 1, d), xb.dtype)
            buf = buf.at[e_safe, pos_c].set(rx, mode="drop")
            part = _ffn(buf[:, :cap_e], wg_l, wu_l, wd_l, cfg.act)
            part = jax.lax.psum(part, "model")
            out_rows = part[e_safe, jnp.clip(pos_c, 0, cap_e - 1)]
            out_rows = out_rows * ok[:, None].astype(out_rows.dtype)

        if sp:
            # every plane now holds full outputs for ALL planes' rows;
            # slice this plane's own dispatched rows back out
            out_rows = jnp.take(
                out_rows.reshape(nmodel, ndata * cap, d),
                jax.lax.axis_index("model"), axis=0)

        # combine all-to-all (reverse direction)
        got = plan.combine(out_rows, dest, slot)  # (M, d)
        got = (got.astype(jnp.float32)
               * keep[:, None].astype(jnp.float32)
               * gts[:, None])
        y2 = jax.ops.segment_sum(got, tok, num_segments=n_loc)
        return y2.reshape(b_loc, s_loc, d).astype(xb.dtype), lb, z

    wspec = {
        "wr": P(),  # router is tiny; replicate
        "wg": rules.spec_for(("expert", "expert_embed", "expert_ffn"),
                             p["w_gate"].shape),
        "wu": rules.spec_for(("expert", "expert_embed", "expert_ffn"),
                             p["w_up"].shape),
        "wd": rules.spec_for(("expert", "expert_ffn", "expert_embed"),
                             p["w_down"].shape),
    }
    y, lb, z = shard_map(
        moe_local,
        mesh=mesh,
        in_specs=(x_spec, wspec["wr"], wspec["wg"], wspec["wu"], wspec["wd"]),
        out_specs=(x_spec, P(), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y = constrain(y, "batch", "seq", None)  # back to the SP residual layout
    return y, {"moe_lb_loss": lb, "moe_z_loss": z}
