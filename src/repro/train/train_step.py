"""Training step: loss, gradients, microbatch accumulation, optimizer.

``TrainState`` is the single checkpointable pytree.  The jitted step
donates the state (in-place buffers on TPU), supports gradient
accumulation via an inner ``lax.scan`` over microbatches, and threads the
MoE aux losses into the objective.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as M
from ..optim import adamw
from ..optim.schedule import warmup_cosine

Z_LOSS = 1e-4
MOE_LB_COEF = 1e-2
MOE_Z_COEF = 1e-3


@dataclasses.dataclass
class TrainState:
    step: Any
    params: Any
    opt: adamw.AdamWState


jax.tree_util.register_dataclass(
    TrainState, data_fields=["step", "params", "opt"], meta_fields=[])


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_accum: int = 1
    adamw: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)


def init_state(key, cfg, tcfg: TrainConfig) -> Tuple[TrainState, Any]:
    """-> (state, logical-axes tree matching state)."""
    from ..models.layers import split_leaves

    leaf_tree = M.init_model(key, cfg)
    params, axes = split_leaves(leaf_tree)
    opt = adamw.init(params, tcfg.adamw)
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params, opt=opt)
    axes_tree = TrainState(
        step=(),
        params=axes,
        opt=adamw.state_logical_axes(opt, axes),
    )
    return state, axes_tree


def token_loss(logits, labels) -> Tuple[jax.Array, jax.Array]:
    """(nll, z-loss) of next-token logits — shared with dist.pipeline."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: gathering along a
    # vocab-SHARDED axis makes the partitioner replicate the fp32 logits
    # (10 GB/device for the 152k-vocab cells); the elementwise+reduce form
    # partitions cleanly (§Perf log)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    tgt = jnp.sum(logits * onehot, axis=-1)
    nll = (logz - tgt).mean()
    zloss = Z_LOSS * (logz ** 2).mean()
    return nll, zloss


def loss_fn(params, cfg, batch: Dict) -> Tuple[jax.Array, Dict]:
    logits, _, aux = M.forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
    )
    nll, zloss = token_loss(logits, batch["labels"])
    total = nll + zloss
    total = total + MOE_LB_COEF * aux["moe_lb_loss"] + MOE_Z_COEF * aux["moe_z_loss"]
    metrics = {
        "loss": nll,
        "z_loss": zloss,
        "moe_lb_loss": aux["moe_lb_loss"],
        "total_loss": total,
    }
    return total, metrics


def _split_microbatches(batch: Dict, n: int) -> Dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return {k: split(v) for k, v in batch.items()}


def compute_grads(params, batch: Dict, cfg, tcfg: TrainConfig):
    """-> (grads, loss metrics), accumulating over microbatches if asked.

    Shared by the single-program step below and the shard_map'd
    data-parallel step in ``train.dist_step`` (which syncs the returned
    grads across ranks before the optimizer update).
    """
    grad_fn = jax.value_and_grad(functools.partial(loss_fn, cfg=cfg),
                                 has_aux=True)
    if tcfg.grad_accum == 1:
        (_, metrics), grads = grad_fn(params, batch=batch)
        return grads, metrics

    micro = _split_microbatches(batch, tcfg.grad_accum)

    def accum(carry, mb):
        g_acc, m_acc = carry
        (_, m), g = grad_fn(params, batch=mb)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
        m_acc = jax.tree.map(jnp.add, m_acc, m)
        return (g_acc, m_acc), None

    zeros_g = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros_m = {k: jnp.zeros((), jnp.float32)
               for k in ("loss", "z_loss", "moe_lb_loss", "total_loss")}
    (grads, metrics), _ = jax.lax.scan(accum, (zeros_g, zeros_m), micro)
    inv = 1.0 / tcfg.grad_accum
    grads = jax.tree.map(lambda g: g * inv, grads)
    metrics = {k: v * inv for k, v in metrics.items()}
    return grads, metrics


def train_step(state: TrainState, batch: Dict, cfg, tcfg: TrainConfig):
    """One optimizer step (possibly accumulating over microbatches)."""
    lr = warmup_cosine(state.step, tcfg.base_lr, tcfg.warmup_steps,
                       tcfg.total_steps)
    grads, metrics = compute_grads(state.params, batch, cfg, tcfg)
    new_params, new_opt, opt_metrics = adamw.update(
        grads, state.opt, state.params, tcfg.adamw, lr=lr)
    metrics.update(opt_metrics)
    new_state = TrainState(step=state.step + 1, params=new_params, opt=new_opt)
    return new_state, metrics


def jit_train_step(cfg, tcfg: TrainConfig, mesh=None, state_shardings=None,
                   batch_sharding=None):
    """Compile-ready step fn; donates the state buffer."""
    fn = functools.partial(train_step, cfg=cfg, tcfg=tcfg)
    kwargs = {}
    if state_shardings is not None:
        kwargs["in_shardings"] = (state_shardings, batch_sharding)
        kwargs["out_shardings"] = (state_shardings, None)
    return jax.jit(fn, donate_argnums=(0,), **kwargs)
