"""shard_map'd data-parallel train step with compressed gradient sync.

The reference ``train_step`` is a single program whose sharding is left
to GSPMD.  This step is the explicit-SPMD counterpart: the batch is
split over a data axis, every rank computes grads for its shard
(reusing ``train_step.compute_grads``), and the cross-rank gradient
all-reduce goes through ``dist.compression.compressed_psum`` — the
shared-scale int8 all-reduce — at one quarter of fp32 bandwidth.  The
optimizer update then runs identically on every rank (the synced grads
are rank-invariant), so the returned state stays replicated.

Numerics: with ``compress=False`` the step is exactly the reference
step up to the reduction split (per-shard mean, then pmean); with
``compress=True`` grads additionally carry the int8 quantization error
bounded by ``0.5 * scale`` per rank (see ``dist.compression``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..dist.compression import compressed_psum
from ..optim import adamw
from ..optim.schedule import warmup_cosine
from . import train_step as TS

BATCH_AXIS = "data"


def sync_grads(grads, axis: str, compress: bool):
    """Cross-rank gradient *mean* — compressed or exact (inside shard_map)."""
    n = jax.lax.psum(1, axis)
    if compress:
        return jax.tree.map(
            lambda g: compressed_psum(g, axis) / n, grads)
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)


def dp_train_step(state: TS.TrainState, batch: Dict, cfg,
                  tcfg: TS.TrainConfig, axis: str = BATCH_AXIS,
                  compress: bool = True):
    """One data-parallel optimizer step; runs INSIDE ``shard_map``.

    ``state`` is replicated, ``batch`` holds this rank's shard.
    """
    lr = warmup_cosine(state.step, tcfg.base_lr, tcfg.warmup_steps,
                       tcfg.total_steps)
    grads, metrics = TS.compute_grads(state.params, batch, cfg, tcfg)
    grads = sync_grads(grads, axis, compress)
    metrics = {k: jax.lax.pmean(v, axis) for k, v in metrics.items()}
    new_params, new_opt, opt_metrics = adamw.update(
        grads, state.opt, state.params, tcfg.adamw, lr=lr)
    metrics.update(opt_metrics)
    new_state = TS.TrainState(step=state.step + 1, params=new_params,
                              opt=new_opt)
    return new_state, metrics


def jit_dp_train_step(cfg, tcfg: TS.TrainConfig, mesh,
                      axis: str = BATCH_AXIS, compress: bool = True,
                      ep_mode: Optional[str] = None):
    """Compile-ready shard_map'd step: state replicated, batch split.

    Drop-in for ``train_step.jit_train_step`` — same ``(state, batch) ->
    (state, metrics)`` signature, so the trainer swaps it in behind a
    flag.  ``ep_mode`` overrides the config's MoE expert-parallel dispatch
    mode ("replicated" | "sp") for MoE archs; ``None`` keeps
    ``cfg.ep_mode``.
    """
    if ep_mode is not None:
        cfg = dataclasses.replace(cfg, ep_mode=ep_mode)
    step = functools.partial(dp_train_step, cfg=cfg, tcfg=tcfg, axis=axis,
                             compress=compress)
    shmapped = shard_map(
        step,
        mesh=mesh,
        in_specs=(P(), P(axis)),
        out_specs=(P(), P()),
        # the synced grads (and hence state/metrics) are rank-invariant by
        # construction, but psum-of-varying is typed varying under both vma
        # systems; skip the replication check instead of pcasting every leaf
        check_vma=False,
    )
    return jax.jit(shmapped, donate_argnums=(0,))
