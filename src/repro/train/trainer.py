"""Fault-tolerant training loop.

Production behaviors, all exercised by tests:
  - periodic async checkpointing with atomic commit
  - resume-from-latest (bit-exact: deterministic data + full-state restore)
  - step watchdog: EMA of step time; steps slower than
    ``straggler_factor x`` EMA are logged as straggler events (on a real
    cluster this feeds preemption/replacement; here it is observable state)
  - failure injection hook for tests (raise at step N, restart, converge)
  - graceful SIGTERM: checkpoint-then-exit (preemption handling)
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import checkpoint as ckpt
from ..data.pipeline import DataConfig, make_batch
from . import train_step as TS


@dataclasses.dataclass
class LoopConfig:
    num_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    keep_metrics: bool = True


class Trainer:
    def __init__(self, cfg, tcfg: TS.TrainConfig, dcfg: DataConfig,
                 loop: LoopConfig, step_fn: Optional[Callable] = None,
                 state_shardings=None, grad_sync: Optional[str] = None,
                 mesh=None):
        """``grad_sync`` selects the shard_map'd data-parallel step
        (``train.dist_step``): ``"psum"`` for the exact all-reduce,
        ``"compressed_psum"`` for the int8 shared-scale one.  Requires a
        ``mesh`` with a data axis; ``None`` keeps the GSPMD reference
        step (or an explicit ``step_fn``)."""
        self.cfg, self.tcfg, self.dcfg, self.loop = cfg, tcfg, dcfg, loop
        if grad_sync is not None:
            if step_fn is not None:
                raise ValueError("pass either step_fn or grad_sync, not both")
            if grad_sync not in ("psum", "compressed_psum"):
                raise ValueError(f"unknown grad_sync {grad_sync!r}")
            if mesh is None:
                raise ValueError("grad_sync needs a mesh with a data axis")
            from . import dist_step as DS
            step_fn = DS.jit_dp_train_step(
                cfg, tcfg, mesh, compress=grad_sync == "compressed_psum")
        self.step_fn = step_fn or TS.jit_train_step(cfg, tcfg)
        self.state_shardings = state_shardings
        self.metrics_log: List[Dict] = []
        self.straggler_events: List[Dict] = []
        self._ema = None
        self._pending_ckpt = None
        self._term = False

    # -- lifecycle -----------------------------------------------------------
    def init_or_restore(self, key) -> TS.TrainState:
        state, _ = TS.init_state(key, self.cfg, self.tcfg)
        last = ckpt.latest_step(self.loop.ckpt_dir)
        if last is not None:
            state = ckpt.restore(self.loop.ckpt_dir, last, state,
                                 shardings=self.state_shardings)
        return state

    def _sigterm(self, signum, frame):  # pragma: no cover - signal path
        self._term = True

    # -- main loop -----------------------------------------------------------
    def run(self, key, fail_at: Optional[int] = None) -> TS.TrainState:
        os.makedirs(self.loop.ckpt_dir, exist_ok=True)
        prev = signal.signal(signal.SIGTERM, self._sigterm)
        state = self.init_or_restore(key)
        try:
            start = int(state.step)
            for step in range(start, self.loop.num_steps):
                if fail_at is not None and step == fail_at:
                    raise RuntimeError(f"injected failure at step {step}")
                batch = make_batch(self.dcfg, step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self._watch(step, dt)
                if self.loop.keep_metrics:
                    self.metrics_log.append(
                        {"step": step, "time_s": dt,
                         **{k: float(v) for k, v in metrics.items()}})
                if self.loop.log_every and step % self.loop.log_every == 0:
                    print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
                next_step = step + 1
                if next_step % self.loop.ckpt_every == 0 or self._term:
                    self._checkpoint(state, next_step)
                if self._term:
                    print("SIGTERM: checkpointed, exiting")
                    break
            self._checkpoint(state, int(state.step))
            return state
        finally:
            # commit any in-flight checkpoint even when the loop raised —
            # a restart must see the last completed save, not lose it to
            # an unjoined writer thread
            self._join_ckpt()
            signal.signal(signal.SIGTERM, prev)

    # -- internals -----------------------------------------------------------
    def _watch(self, step: int, dt: float):
        if self._ema is None:
            self._ema = dt
        if dt > self.loop.straggler_factor * self._ema and step > 2:
            self.straggler_events.append({"step": step, "time_s": dt,
                                          "ema_s": self._ema})
        self._ema = 0.9 * self._ema + 0.1 * dt

    def _checkpoint(self, state, step: int):
        self._join_ckpt()
        self._pending_ckpt = ckpt.save(self.loop.ckpt_dir, step, state)

    def _join_ckpt(self):
        if self._pending_ckpt is not None:
            self._pending_ckpt.join()
            self._pending_ckpt = None
