"""Serving engine: slot-granular continuous batching with chunked decode.

``ServeEngine`` owns a fixed pool of batch slots backed by ONE persistent
slotted cache (allocated at construction, never reallocated): each layer's
``LayerCache`` carries a per-slot write cursor (``pos``: (B,)), so every
slot sits at its own depth.  The request lifecycle is:

  submit -> (slot frees up) -> unpadded B=1 prefill -> ``write_prompt``
  copies the prefill cache into the freed slot -> slot decodes alongside
  requests admitted earlier -> completion (``max_new_tokens`` or
  ``eos_id``) -> ``reset_slot``.

Admission is *slot-granular*: a freed slot is refilled between decode
chunks, mid-flight for everyone else — no wave boundaries.  Prefill runs
unpadded at batch 1, so admission is bit-exact with running the request
alone (no left-pad pollution) at the cost of one compile-cache entry per
distinct prompt length.

Decode runs in one of two modes:

  ``"chunked"`` (default) — an on-device ``lax.while_loop`` advances up
    to ``chunk_size`` tokens per launch, carrying (tokens, caches, pos,
    remaining-budget) with per-slot stop conditions; the host syncs once
    per CHUNK (fetching the token buffer), not once per token.  Per
    request that is ceil(tokens/chunk_size) + 1 host syncs instead of
    O(tokens) — the Task Bench §IV-B dispatch/sync floor amortized.
  ``"host"`` — the legacy per-token loop (one jitted step + one device
    round-trip per token), kept as the measurement baseline.

Both modes trace the same ``M.forward`` step, so they are bit-exact with
each other.  ``engine.stats`` counts prefills / decode steps / chunk
launches / host syncs for the structural tests and the ``serve_load``
bench family.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M
from ..models.cache import (LayerCache, init_caches, reset_slot,
                            stack_caches, write_prompt)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None  # early-stop token (emitted, then stop)
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # wallclock marks (perf_counter seconds) for TTFT/TPOT measurement
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


def serve_step(params, tokens, caches, pos, *, cfg):
    """One decode step for the whole batch: (B,1) tokens -> (B,1) next.

    ``pos`` may be scalar (all rows at the same depth — the dry-run cells)
    or per-slot (B,) to match per-slot cache cursors.
    """
    logits, new_caches, _ = M.forward(params, cfg, tokens=tokens,
                                      caches=caches, pos=pos,
                                      last_token_only=True)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt[:, None], new_caches


def _with_start(caches, start):
    """Attach per-slot start offsets to the attention layers of ``caches``."""
    def one(c, layer_dim):
        if c.kind not in ("full", "ring"):
            return c
        s = start
        if layer_dim:  # pre-stacked: every leaf leads with the layer dim
            s = jnp.broadcast_to(start, (c.k.shape[0],) + start.shape)
        return dataclasses.replace(c, start=s)

    if isinstance(caches, LayerCache):
        return one(caches, layer_dim=True)
    return [one(c, layer_dim=False) for c in caches]


def prefill(params, tokens, caches, pos=0, *, cfg, pad=None):
    """Batched prefill; returns ((B,1) first sampled token, new caches).

    ``pad`` (optional (B,) int32) gives the left-pad width of each row.
    When set, attention layers store it as a per-slot ``start`` offset:
    pad rows land at negative key positions and are masked out, and RoPE
    positions are rebased so each row's first REAL token sits at position
    0 — a padded-batch prefill then matches per-row unpadded prefills
    exactly for attention layers.  Recurrent/SSM state still absorbs the
    pad rows (their scans have no position mask); the serving engine
    sidesteps this entirely by prefilling unpadded at B=1.
    """
    if pad is not None:
        pad = jnp.asarray(pad, jnp.int32)
        caches = _with_start(caches, pad)
        pos = pos - pad  # (B,): rebased RoPE positions per row
    logits, new_caches, _ = M.forward(params, cfg, tokens=tokens,
                                      caches=caches, pos=pos,
                                      last_token_only=True)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt[:, None], new_caches


def _prefill_one(params, tokens, *, cfg, max_len):
    """Unpadded single-request prefill; returns (first token scalar, caches).

    Fresh B=1 caches are created inside the trace (XLA fuses the zeros
    away for the rows the prefill overwrites); the engine's persistent
    B=slots pool is never reallocated.
    """
    caches = init_caches(cfg, 1, max_len)
    logits, new_caches, _ = M.forward(params, cfg, tokens=tokens,
                                      caches=caches, pos=0,
                                      last_token_only=True)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt[0], new_caches


def _decode_chunk(params, tokens, caches, pos, remaining, eos, *, cfg, chunk):
    """Advance up to ``chunk`` decode steps on device; one host sync total.

    Carries (step t, (B,1) tokens, caches, (B,) pos, (B,) remaining
    budget, (B, chunk) output buffer) through a ``lax.while_loop``; stops
    early when every slot's budget hits 0.  Per-slot stops: ``remaining``
    counts tokens still owed (0 = dead slot), and emitting ``eos[b]``
    (when >= 0) zeroes the budget.  Dead slots keep stepping harmlessly —
    batch rows are independent and their writes land in rows that
    ``write_prompt`` overwrites at the next admission.

    Output buffer rows are -1-sentinel-filled; entry (b, t) holds the
    token slot b emitted at step t iff it was live then.
    """
    B = tokens.shape[0]
    out0 = jnp.full((B, chunk), -1, jnp.int32)

    def cond(carry):
        t, _toks, _cs, _pos, rem, _out = carry
        return (t < chunk) & jnp.any(rem > 0)

    def body(carry):
        t, toks, cs, pos, rem, out = carry
        logits, cs2, _ = M.forward(params, cfg, tokens=toks, caches=cs,
                                   pos=pos, last_token_only=True)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (B,)
        live = rem > 0
        out = out.at[:, t].set(jnp.where(live, nxt, -1), mode="drop")
        rem2 = jnp.where(live, rem - 1, 0)
        rem2 = jnp.where(live & (eos >= 0) & (nxt == eos), 0, rem2)
        return (t + 1, nxt[:, None], cs2, pos + 1, rem2, out)

    carry = (jnp.int32(0), tokens, caches, pos, remaining, out0)
    t, toks, cs, pos, rem, out = jax.lax.while_loop(cond, body, carry)
    return out, toks, cs, pos, rem, t


class ServeEngine:
    """Continuous-batching engine over a persistent slotted cache.

    Args:
      batch_slots: size of the fixed slot pool (compiled batch width).
      max_len: per-slot cache rows; submit() enforces
        len(prompt) + max_new_tokens <= max_len.
      chunk_size: decode steps per device launch in chunked mode.
      decode_mode: "chunked" (on-device while_loop, 1 sync/chunk) or
        "host" (per-token loop, 1 sync/token — the baseline).
    """

    def __init__(self, cfg, params, batch_slots: int = 4, max_len: int = 512,
                 chunk_size: int = 8, decode_mode: str = "chunked"):
        assert cfg.supports_decode, f"{cfg.name} is encoder-only"
        if decode_mode not in ("chunked", "host"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        self.cfg, self.params = cfg, params
        self.slots = batch_slots
        self.max_len = max_len
        self.chunk_size = int(chunk_size)
        self.decode_mode = decode_mode

        # ONE persistent slotted cache for the life of the engine.
        per_layer = init_caches(cfg, batch_slots, max_len, per_slot_pos=True)
        pattern = cfg.pattern_for_depth()
        self._stacked = bool(cfg.scan_layers) and len(set(pattern)) == 1
        self.caches = stack_caches(per_layer) if self._stacked else per_layer

        self._decode = jax.jit(functools.partial(serve_step, cfg=cfg))
        self._prefill1 = jax.jit(
            functools.partial(_prefill_one, cfg=cfg, max_len=max_len))
        self._chunk = jax.jit(functools.partial(
            _decode_chunk, cfg=cfg, chunk=self.chunk_size))
        self._admit_fn = jax.jit(write_prompt)
        self._reset_fn = jax.jit(reset_slot)

        B = batch_slots
        self.cur = jnp.zeros((B, 1), jnp.int32)   # next input token per slot
        self._pos = np.zeros((B,), np.int32)      # host mirror of cache.pos
        self._rem = np.zeros((B,), np.int32)      # tokens still owed per slot
        self._eos = np.full((B,), -1, np.int32)   # eos id per slot (-1: none)
        self._slot_req: List[Optional[Request]] = [None] * B
        self._queue: List[Request] = []
        self._next_rid = 0
        self.stats = {"prefills": 0, "decode_steps": 0, "chunk_launches": 0,
                      "host_syncs": 0, "tokens_generated": 0}

    # ------------------------------------------------------------- frontend
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"len(prompt)={len(prompt)} + max_new_tokens={max_new_tokens} "
                f"exceeds max_len={self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid, prompt, max_new_tokens, eos_id=eos_id)
        r.t_submit = time.perf_counter()
        self._queue.append(r)
        return rid

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(
            r is not None for r in self._slot_req)

    # ------------------------------------------------------------ lifecycle
    def _complete(self, slot: int, results: Dict[int, List[int]]) -> Request:
        r = self._slot_req[slot]
        r.done = True
        r.t_done = time.perf_counter()
        results[r.rid] = r.out
        self._slot_req[slot] = None
        self._rem[slot] = 0
        self._pos[slot] = 0
        self.caches = self._reset_fn(self.caches, slot)
        return r

    def _admit(self, results: Dict[int, List[int]]) -> List[Request]:
        """Prefill queued requests into free slots; returns any that
        completed at prefill (max_new_tokens == 1 or instant eos)."""
        finished = []
        for slot in range(self.slots):
            if not self._queue or self._slot_req[slot] is not None:
                continue
            r = self._queue.pop(0)
            tok, pf_caches = self._prefill1(
                self.params, jnp.asarray(r.prompt)[None, :])
            first = int(tok)  # host sync: first token of this request
            self.stats["prefills"] += 1
            self.stats["host_syncs"] += 1
            self.stats["tokens_generated"] += 1
            r.t_first = time.perf_counter()
            r.out.append(first)
            if len(r.out) >= r.max_new_tokens or (
                    r.eos_id is not None and first == r.eos_id):
                r.done = True
                r.t_done = r.t_first
                results[r.rid] = r.out
                finished.append(r)
                continue
            self.caches = self._admit_fn(self.caches, slot, pf_caches)
            self.cur = self.cur.at[slot, 0].set(first)
            self._pos[slot] = len(r.prompt)
            self._rem[slot] = r.max_new_tokens - 1
            self._eos[slot] = -1 if r.eos_id is None else r.eos_id
            self._slot_req[slot] = r
        return finished

    def _harvest(self, slot_tokens, results) -> List[Request]:
        """Append per-slot tokens; complete slots whose budget hit 0."""
        finished = []
        for slot, toks in enumerate(slot_tokens):
            r = self._slot_req[slot]
            if r is None:
                continue
            for t in toks:
                r.out.append(int(t))
                self.stats["tokens_generated"] += 1
            if self._rem[slot] <= 0:
                finished.append(self._complete(slot, results))
        return finished

    def _step_chunked(self, results) -> List[Request]:
        out, self.cur, self.caches, _pos_dev, rem, t = self._chunk(
            self.params, self.cur, self.caches, jnp.asarray(self._pos),
            jnp.asarray(self._rem), jnp.asarray(self._eos))
        out = np.asarray(out)            # ONE host sync for the whole chunk
        rem = np.asarray(rem)
        steps = int(t)
        self.stats["chunk_launches"] += 1
        self.stats["host_syncs"] += 1
        self.stats["decode_steps"] += steps
        self._pos += steps               # all slots advance together
        live = [s for s in range(self.slots) if self._slot_req[s] is not None]
        slot_tokens = [[] for _ in range(self.slots)]
        for s in live:
            row = out[s]
            slot_tokens[s] = [int(v) for v in row[row >= 0]]
        self._rem[:] = rem
        return self._harvest(slot_tokens, results)

    def _step_host(self, results) -> List[Request]:
        self.cur, self.caches = self._decode(
            self.params, self.cur, self.caches, jnp.asarray(self._pos))
        cur = np.asarray(self.cur)       # one host sync PER TOKEN
        self.stats["decode_steps"] += 1
        self.stats["host_syncs"] += 1
        self._pos += 1
        slot_tokens = [[] for _ in range(self.slots)]
        for s in range(self.slots):
            r = self._slot_req[s]
            if r is None:
                continue
            tok = int(cur[s, 0])
            slot_tokens[s] = [tok]
            self._rem[s] -= 1
            if r.eos_id is not None and tok == r.eos_id:
                self._rem[s] = 0
        return self._harvest(slot_tokens, results)

    def step(self, results: Optional[Dict[int, List[int]]] = None
             ) -> List[Request]:
        """One scheduler tick: admit into free slots, then decode one chunk
        (chunked mode) or one token (host mode).  Returns the requests that
        completed this tick."""
        results = results if results is not None else {}
        finished = self._admit(results)
        if any(r is not None for r in self._slot_req):
            if self.decode_mode == "chunked":
                finished += self._step_chunked(results)
            else:
                finished += self._step_host(results)
        return finished

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue with continuous batching; returns rid -> tokens."""
        results: Dict[int, List[int]] = {}
        while self.has_work:
            self.step(results)
        return results
