"""Serving engine: batched prefill + decode with continuous batching.

``ServeEngine`` maintains a fixed pool of batch slots over jitted
``prefill`` and ``decode_step`` programs (compiled once per shape class).
Requests are admitted into free slots as others complete — the
vLLM-style continuous-batching control loop reduced to its scheduling
essence, host-side and observable.  The decode step is exactly what the
``decode_*``/``long_*`` dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M
from ..models.cache import init_caches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def serve_step(params, tokens, caches, pos, *, cfg):
    """One decode step for the whole batch: (B,1) tokens -> (B,1) next."""
    logits, new_caches, _ = M.forward(params, cfg, tokens=tokens,
                                      caches=caches, pos=pos,
                                      last_token_only=True)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt[:, None], new_caches


def prefill(params, tokens, caches, pos=0, *, cfg):
    logits, new_caches, _ = M.forward(params, cfg, tokens=tokens,
                                      caches=caches, pos=pos,
                                      last_token_only=True)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt[:, None], new_caches


class ServeEngine:
    def __init__(self, cfg, params, batch_slots: int = 4, max_len: int = 512):
        assert cfg.supports_decode, f"{cfg.name} is encoder-only"
        self.cfg, self.params = cfg, params
        self.slots = batch_slots
        self.max_len = max_len
        self._decode = jax.jit(functools.partial(serve_step, cfg=cfg))
        self._prefill = jax.jit(functools.partial(prefill, cfg=cfg),
                                static_argnames=())
        self._queue: List[Request] = []
        self._next_rid = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new_tokens))
        return rid

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue with continuous batching; returns rid -> tokens.

        Prompts in a wave are right-aligned (left-padded) to a shared
        length so one prefill serves the whole wave.
        """
        results: Dict[int, List[int]] = {}
        while self._queue:
            wave = self._queue[: self.slots]
            self._queue = self._queue[self.slots:]
            plen = max(len(r.prompt) for r in wave)
            B = len(wave)
            toks = np.zeros((B, plen), np.int32)
            for i, r in enumerate(wave):
                toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with BOS=0
            caches = init_caches(self.cfg, B, max_len=self.max_len)
            cur, caches = self._prefill(self.params, tokens=jnp.asarray(toks),
                                        caches=caches, pos=0)
            pos = plen
            live = {i: r for i, r in enumerate(wave)}
            for i, r in live.items():
                r.out.append(int(cur[i, 0]))
            budget = max(r.max_new_tokens for r in wave) - 1
            for _ in range(max(budget, 0)):
                cur, caches = self._decode(self.params, tokens=cur,
                                           caches=caches, pos=jnp.int32(pos))
                pos += 1
                done_now = []
                for i, r in live.items():
                    if len(r.out) < r.max_new_tokens:
                        r.out.append(int(cur[i, 0]))
                    if len(r.out) >= r.max_new_tokens:
                        done_now.append(i)
                for i in done_now:
                    r = live.pop(i)
                    r.done = True
                    results[r.rid] = r.out
                if not live:
                    break
            for r in live.values():
                r.done = True
                results[r.rid] = r.out
        return results
