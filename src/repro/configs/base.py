"""Model configuration schema, registry, and assigned input shapes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

_REGISTRY: Dict[str, "ModelConfig"] = {}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern, cycled over depth: entries from
    #   {"attn", "moe", "ssd", "rglru", "local_attn"}
    block_pattern: Tuple[str, ...] = ("attn",)
    # attention
    qkv_bias: bool = False
    window: Optional[int] = None        # sliding-window for "attn" blocks
    local_window: Optional[int] = None  # window for "local_attn" blocks
    rope_theta: float = 10000.0
    mrope: bool = False                 # Qwen2-VL multimodal RoPE flag
    causal: bool = True                 # False => encoder-only
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual_ff: int = 0          # Arctic: parallel dense MLP width
    # expert-parallel dispatch mode for the a2a path (models.moe):
    #   "replicated" — tokens replicated over `model`; dispatch a2a
    #                  duplicated per model plane
    #   "sp"         — SP-aware: each model plane all-to-alls only its
    #                  sequence shard (per-plane a2a volume / |model|)
    ep_mode: str = "replicated"
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # RG-LRU (RecurrentGemma/Griffin)
    lru_width: int = 0
    conv1d_width: int = 4
    # embeddings / head / mlp
    tie_embeddings: bool = False
    act: str = "silu"
    mlp_gated: bool = True
    norm: str = "rms"                   # rms | layer
    norm_eps: float = 1e-6
    # modality frontend stub (inputs arrive as embeddings)
    frontend: Optional[str] = None      # None | "vision" | "audio"
    # numerics / execution
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"    # huge-MoE configs drop to bfloat16
    remat: str = "full"                 # none | full | selective
    scan_layers: bool = True
    kernel_impl: str = "auto"           # kernels.ops dispatch
    moe_impl: str = "auto"
    # shape applicability
    supports_decode: bool = True        # False for encoder-only
    subquadratic: bool = False          # may run long_500k

    def pattern_for_depth(self) -> Tuple[str, ...]:
        """The concrete per-layer block kinds (len == num_layers)."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def params_dense(self) -> int:
        """Rough non-embedding dense param count (for 6ND roofline)."""
        return _count_params(self, active_only=False)

    @property
    def params_active(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        return _count_params(self, active_only=True)


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d, f = cfg.d_model, cfg.d_ff
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    total = 2 * cfg.vocab_size * d if not cfg.tie_embeddings else cfg.vocab_size * d
    for kind in cfg.pattern_for_depth():
        if kind in ("attn", "local_attn", "moe"):
            total += d * (H + 2 * Hkv) * Dh + H * Dh * d  # qkvo
        if kind == "attn" or kind == "local_attn":
            total += 3 * d * f if cfg.mlp_gated else 2 * d * f
        elif kind == "moe":
            e = cfg.num_experts_per_tok if active_only else cfg.num_experts
            total += e * 3 * d * f
            if cfg.dense_residual_ff:
                total += 3 * d * cfg.dense_residual_ff
        elif kind == "ssd":
            d_in = cfg.ssm_expand * d
            ng, st = cfg.ssm_ngroups, cfg.ssm_state
            total += d * (2 * d_in + 2 * ng * st + d_in // cfg.ssm_headdim)
            total += d_in * d
        elif kind == "rglru":
            w = cfg.lru_width or d
            total += 2 * d * w + w * d + 3 * w  # in x2, out, gates
            total += 3 * d * f if cfg.mlp_gated else 2 * d * f
    return total


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import ALL_ARCHS  # noqa: F401  (triggers registration imports)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def config_names():
    from . import ALL_ARCHS  # noqa: F401

    return sorted(_REGISTRY)


def reduced(cfg: ModelConfig, seq_ok: bool = True) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests (one fwd/train step)."""
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=max(2, 2 * len(cfg.block_pattern)),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        dtype="float32",
        scan_layers=cfg.scan_layers,
        window=min(cfg.window, 64) if cfg.window else None,
        local_window=min(cfg.local_window, 32) if cfg.local_window else None,
    )
    if cfg.num_experts:
        changes.update(num_experts=4, num_experts_per_tok=2)
    if cfg.dense_residual_ff:
        changes.update(dense_residual_ff=128)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.lru_width:
        changes.update(lru_width=128)
    new = replace(cfg, **changes)
    object.__setattr__(new, "_registered", False)
    return new


# ------------------------------------------------------- assigned shapes
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "long_decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.kind in ("decode", "long_decode") and not cfg.supports_decode:
        return False, "encoder-only: no decode step"
    if shape.kind == "long_decode" and not cfg.subquadratic:
        return False, "full quadratic attention: long_500k out of scope"
    return True, ""
