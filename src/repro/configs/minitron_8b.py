"""Minitron-8B [arXiv:2407.14679]: pruned Nemotron, 256k vocab, GQA kv=8.

Nemotron lineage: squared-ReLU non-gated MLP; head_dim 128 (d/H=128)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    mlp_gated=False,
    act="gelu",
))
