"""Qwen2-VL-2B [arXiv:2409.12191]: M-RoPE, dynamic-resolution vision.

The transformer BACKBONE only (per assignment): the vision frontend is a
stub — input_specs() provides precomputed patch embeddings alongside text
tokens; M-RoPE with coincident position streams (text-only backbone)
reduces exactly to 1-D RoPE (see layers.apply_rope)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,          # not 16-divisible -> context-parallel fallback
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    mrope=True,
    frontend="vision",
    tie_embeddings=True,
))
