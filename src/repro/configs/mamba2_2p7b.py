"""Mamba-2 2.7B [arXiv:2405.21060]: attention-free SSD stack."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,      # not 16-divisible: embed dim picks up TP instead
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    tie_embeddings=True,
    subquadratic=True,
))
