"""HuBERT X-Large [arXiv:2106.07447]: encoder-only audio transformer.

Backbone only: the conv waveform frontend is a stub — input_specs()
provides precomputed frame embeddings.  Masked-unit prediction over 504
k-means targets; no decode shapes (encoder-only)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,        # k-means cluster units; replicated head (tiny)
    causal=False,
    mlp_gated=False,
    act="gelu",
    norm="layer",
    frontend="audio",
    supports_decode=False,
))
