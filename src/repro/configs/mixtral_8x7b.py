"""Mixtral 8x7B [arXiv:2401.04088; hf]: 32L MoE 8e top-2, GQA kv=8, SWA."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("moe",),
    num_experts=8,
    num_experts_per_tok=2,
    ep_mode="sp",         # SP-aware EP: per-plane dispatch a2a / |model|
    window=4096,          # sliding-window attention (Mistral lineage)
    rope_theta=1e6,
    subquadratic=True,    # SWA bounds the KV working set -> long_500k runs
))
