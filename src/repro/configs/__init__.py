"""Assigned architecture configs (public-literature, see each module)."""
from . import (  # noqa: F401
    arctic_480b,
    hubert_xlarge,
    mamba2_2p7b,
    minitron_8b,
    mixtral_8x7b,
    qwen1p5_0p5b,
    qwen2_72b,
    qwen2_vl_2b,
    recurrentgemma_2b,
    yi_6b,
)
from .base import (
    SHAPES,
    InputShape,
    ModelConfig,
    config_names,
    get_config,
    reduced,
    shape_applicable,
)

ALL_ARCHS = [
    "mixtral-8x7b", "arctic-480b", "mamba2-2.7b", "recurrentgemma-2b",
    "yi-6b", "qwen1.5-0.5b", "qwen2-72b", "minitron-8b", "qwen2-vl-2b",
    "hubert-xlarge",
]
