"""RecurrentGemma-2B / Griffin [arXiv:2402.19427]: RG-LRU + local attention,
2 recurrent blocks per 1 local-attention block; GQA kv=1 (MQA)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,          # not 16-divisible -> context-parallel fallback
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    lru_width=2560,
    act="gelu",
    scan_layers=False,     # heterogeneous 3-block period, 26 layers: unroll
    subquadratic=True,
))
