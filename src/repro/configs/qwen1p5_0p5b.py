"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B]: QKV bias, MHA (kv=16)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
))
