"""Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]: 35L, 128e top-2
MoE + dense residual MLP; 56 heads (not 16-divisible -> context-parallel
attention via the sharding fallback); bf16 optimizer state for memory."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    block_pattern=("moe",),
    num_experts=128,
    num_experts_per_tok=2,
    dense_residual_ff=4864,   # dense MLP in parallel with the MoE branch
    opt_state_dtype="bfloat16",
))
