"""FlashAttention-2-style fused attention, Pallas TPU.

Forward-only fused attention with online softmax: grid is
``(batch, q_heads, q_blocks, k_blocks)`` with the k dimension minor-most
(sequential on TPU), accumulating into VMEM scratch.  Supports causal
masking, sliding windows (Mistral SWA), GQA head grouping, and a query
offset for chunked prefill against an existing KV cache.

Tiling: (block_q x d) query tiles and (block_k x d) key/value tiles staged
in VMEM; both default to 128 to keep the MXU contraction dims
hardware-aligned.  Out-of-window key blocks are masked rather than skipped
(a production TPU kernel would remap the k-grid; the skip is a pure
scheduling optimization with no semantic effect, see DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1, 1, bq, d), (1, 1, bk, d), (1, 1, bk, d)
    o_ref,                # (1, 1, bq, d)
    acc_ref, m_ref, l_ref,  # VMEM scratch: (bq, d) f32, (bq,) f32, (bq,) f32
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)

    qpos = q_offset + iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: m_new stays NEG_INF; exp(NEG_INF - NEG_INF)=1 would
    # pollute l, so zero those rows explicitly.
    row_alive = m_new > NEG_INF / 2
    p = jnp.where(row_alive[:, None], p, 0.0)
    alpha = jnp.where(row_alive, alpha, 0.0)

    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k
    scale = scale if scale is not None else float(1.0 / np.sqrt(D))

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
