"""Pallas TPU kernels for the perf-critical compute layers.

- bodies: composable in-kernel task bodies (step functions + masked loop)
  shared by the jitted backends, these kernels, and the fused megakernel
- compute/memory: the paper's two task kernels, TPU-tiled
- flash_attention: fused online-softmax attention (causal/SWA/GQA)
- ssd: Mamba-2 state-space-duality chunked kernel
- ops: jit'd dispatchers (pallas | interpret | ref)
- ref: pure-jnp oracles for every kernel
"""
from . import bodies, ops, ref
from .compute import taskbench_compute
from .flash_attention import flash_attention
from .memory import taskbench_memory
from .ssd import ssd_chunked

__all__ = [
    "bodies",
    "ops",
    "ref",
    "taskbench_compute",
    "taskbench_memory",
    "flash_attention",
    "ssd_chunked",
]
