"""Composable in-kernel Task Bench bodies shared by every execution layer.

The three Task Bench kernel inner loops (compute / compute_mxu / memory)
written as composable step functions plus one masked iteration loop, in a
form legal both inside jitted XLA programs (``backends.body``) and inside
other Pallas kernels (``backends.megakernel``, ``kernels.compute``,
``kernels.memory``).  One code path -> bit-exact conformance everywhere:
the jitted backends and the fused megakernel literally execute these same
traced operations.

Mosaic (Pallas TPU) legality constraints honored here:

* column-vector ``(W, 1)`` working shapes — never rank-1 intermediates
  (Mosaic cannot lower 1-D vector ops on this toolchain)
* no uint32 arithmetic (checksums are int32-exact: values < 2^20)
* no captured array constants — the MXU weight is an explicit argument so
  kernels can pass it in as a ref instead of baking in a (128,128) const
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.kernel_ref import COMPUTE_C, MEM_BIAS, MEM_SCALE, mxu_weight
from ..core.kernel_spec import COMPUTE_TILE, MXU_DIM, KernelSpec

# seeds the kernel state with ``start + acc * FOLD_BLOCK``: rounds to
# exactly ``start`` in float32 (acc < 2^20 keeps the increment below half
# an ulp of every start value used) but blocks XLA constant folding, so
# the kernel loop always executes at run time (see backends.body)
FOLD_BLOCK = 2.0**-46


def compute_step(a):
    """One paper compute-kernel iteration: A = A*A - C (one FMA/element)."""
    return a * a - COMPUTE_C


def memory_step(a):
    """One paper memory-kernel window update: read-scale-write."""
    return a * MEM_SCALE + MEM_BIAS


def mxu_step(b, w):
    """One MXU-kernel iteration: batched matmul, scaled back into orbit."""
    inv = jnp.float32(1.0 / MXU_DIM)
    return jnp.einsum("wij,jk->wik", b, w) * inv + b * jnp.float32(0.5)


def masked_loop(step_fn: Callable, state, iters, max_iters: int,
                dynamic: bool = False):
    """Run the kernel loop with per-column iteration counts.

    Static mode: ``max_iters`` steps with a per-column keep-old mask —
    what vectorized runtimes must do, and why they cannot exploit load
    imbalance (paper §V-G).  Dynamic mode: traced trip count
    (``while``-loop lowering) — per-task systems genuinely run fewer
    iterations for short tasks.  Values are bitwise identical.

    ``iters`` may be ``(W,)`` or ``(W, 1)``; ``state`` has leading W.
    """
    if dynamic:
        trip = jnp.max(iters)
        return jax.lax.fori_loop(0, trip, lambda k, st: step_fn(k, st), state)
    keep_shape = (state.shape[0],) + (1,) * (state.ndim - 1)

    def body(k, st):
        new = step_fn(k, st)
        keep = (k < iters).reshape(keep_shape)
        return jnp.where(keep, new, st)

    return jax.lax.fori_loop(0, max_iters, body, state)


def memory_geometry(kernel: KernelSpec) -> Tuple[int, int, int]:
    """(span, size, nwin) in f32 elements for the memory kernel's window
    walk — the single definition shared with ``core.kernel_ref``'s math."""
    span = max(1, kernel.span_bytes // 4)
    size = max(span, kernel.scratch_bytes // 4)
    size -= size % span  # whole number of windows
    return span, size, size // span


def run_kernel_columns(kernel: KernelSpec, iters_col, seed_col,
                       max_iters: int, dynamic: bool = False,
                       mxu_w: Optional[jax.Array] = None):
    """The shared task-kernel body in column-vector form.

    ``iters_col``/``seed_col`` are ``(W, 1)``; returns ``(W, 1)`` f32
    results.  ``mxu_w`` lets Pallas callers pass the MXU weight as a ref
    value (kernels must not capture array constants); jitted callers leave
    it None and get the host-side constant.
    """
    width = seed_col.shape[0]

    if kernel.kind == "empty":
        # No work; preserve the data dependency so scheduling is honest.
        return seed_col * jnp.float32(0.0)

    if kernel.kind == "compute":
        tile = jnp.float32(0.5) + seed_col[:, :, None]
        tile = jnp.broadcast_to(tile, (width,) + COMPUTE_TILE)
        out = masked_loop(lambda k, a: compute_step(a), tile, iters_col,
                          max_iters, dynamic)
        return out[:, 0, :][:, 0:1]

    if kernel.kind == "compute_mxu":
        b = jnp.float32(0.25) + seed_col[:, :, None]
        b = jnp.broadcast_to(b, (width, MXU_DIM, MXU_DIM))
        w = jnp.asarray(mxu_weight()) if mxu_w is None else mxu_w
        out = masked_loop(lambda k, bb: mxu_step(bb, w), b, iters_col,
                          max_iters, dynamic)
        return out[:, 0, :][:, 0:1]

    if kernel.kind == "memory":
        span, size, nwin = memory_geometry(kernel)
        x = jnp.float32(1.0) + seed_col
        x = jnp.broadcast_to(x, (width, size))

        def step(k, st):
            wstart = (k % nwin) * span
            window = jax.lax.dynamic_slice(st, (0, wstart), (width, span))
            return jax.lax.dynamic_update_slice(st, memory_step(window),
                                                (0, wstart))

        out = masked_loop(step, x, iters_col, max_iters, dynamic)
        return out[:, 0:1]

    raise ValueError(kernel.kind)
