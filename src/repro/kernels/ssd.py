"""Mamba-2 SSD (state-space duality) chunked kernel, Pallas TPU.

Implements the matmul-form SSD algorithm (Dao & Gu, arXiv:2405.21060) the
way a TPU wants it: the sequence is split into chunks of ``chunk``
positions; intra-chunk work is three MXU matmuls over (chunk x chunk) and
(chunk x state) tiles staged in VMEM, and the inter-chunk recurrence is a
scalar-decay update on a persistent (headdim x state) VMEM scratch carried
across the sequential chunk grid dimension.

Grid: (batch, heads, n_chunks) — chunks minor-most so the state scratch
walks the sequence in order.  The cumulative within-chunk log-decay is
computed with a lower-triangular matmul (MXU) rather than a serial cumsum.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,   # (1, Q, 1, P)
    dt_ref,  # (1, Q, 1)
    a_ref,   # (1,)
    b_ref,   # (1, Q, 1, N)
    c_ref,   # (1, Q, 1, N)
    y_ref,   # (1, Q, 1, P) out
    hT_ref,  # (1, 1, P, N) out (final state)
    h_ref,   # VMEM scratch (P, N) f32
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (Q,)
    a = a_ref[0].astype(jnp.float32)             # scalar
    b = b_ref[0, :, 0, :].astype(jnp.float32)    # (Q, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)    # (Q, N)

    la = dt * a  # (Q,) negative log-decays
    # inclusive cumsum via lower-triangular matmul (MXU-friendly)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    cum = jax.lax.dot_general(
        tri.astype(jnp.float32), la, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q,)

    # intra-chunk: scores[i,j] = (c_i . b_j) * exp(cum_i - cum_j) for j <= i
    seg = cum[:, None] - cum[None, :]
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(
        cb * decay, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, P)

    # inter-chunk: y += (c * exp(cum)) @ h_prev^T
    h = h_ref[...]  # (P, N)
    c_in = c * jnp.exp(cum)[:, None]
    y += jax.lax.dot_general(
        c_in, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: h = exp(cum[-1]) * h + x^T @ (b * (exp(cum[-1]-cum)*dt))
    tail = jnp.exp(cum[chunk - 1] - cum) * dt  # (Q,)
    b_in = b * tail[:, None]
    h_new = jnp.exp(cum[chunk - 1]) * h + jax.lax.dot_general(
        x, b_in, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    h_ref[...] = h_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _final():
        hT_ref[0, 0] = h_new


def ssd_chunked(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    D: Optional[jax.Array] = None,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final state (B,H,P,N)); zero initial state."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ic: (b, ic, h)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ic: (b, ic, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ic: (b, ic, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    if D is not None:
        y = (y.astype(jnp.float32)
             + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
             ).astype(x.dtype)
    return y, hT
