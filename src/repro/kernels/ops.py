"""Jitted dispatch wrappers: Pallas on TPU, XLA reference elsewhere.

``impl`` selects the path:
  - "auto":   Pallas when the default backend is TPU, else the jnp reference
  - "pallas": Pallas compiled (TPU only)
  - "interpret": Pallas interpret mode (CPU validation of the kernel body)
  - "ref":    pure-jnp oracle

Models call these entry points; the multi-pod dry-run lowers the reference
path (Pallas cannot lower for the CPU backend), which is also the path whose
HLO feeds the roofline analysis.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import ref as _ref
from . import ssd as _ssd
from .compute import taskbench_compute as _tb_compute
from .memory import taskbench_memory as _tb_memory


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# ----------------------------------------------------------- task bench
def taskbench_compute(tiles, iters, max_iters: int, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "ref":
        from ..core.kernel_ref import COMPUTE_C

        # masked per-column loop, same semantics as the kernel
        def step(k, a):
            keep = (k < iters)[:, None, None]
            return jnp.where(keep, a * a - COMPUTE_C, a)

        return jax.lax.fori_loop(0, max_iters, step, tiles)
    return _tb_compute(tiles, iters, max_iters, interpret=(impl == "interpret"))


def taskbench_memory(x, iterations: int, span: int, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.taskbench_memory_ref(x, iterations, span)
    return _tb_memory(x, iterations, span, interpret=(impl == "interpret"))


# ------------------------------------------------------------- attention
def attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,  # int (static, any impl) or traced scalar (ref impl only)
    kv_positions: Optional[jax.Array] = None,  # ring caches (ref impl only)
    scale: Optional[float] = None,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    impl = _resolve(impl)
    if impl != "ref" and (kv_positions is not None or not isinstance(q_offset, int)):
        # decode-time dynamic offsets/ring buffers run the XLA path; the
        # Pallas kernel covers the static-offset train/prefill hot spot.
        impl = "ref"
    if impl == "ref":
        Sq, Skv = q.shape[1], k.shape[1]
        if Sq >= 2048 and Skv >= 8192:
            # long prefill: bound the logits footprint (inference path; the
            # Pallas kernel is the TPU answer, this is the XLA one)
            return _ref.attention_ref_chunked(
                q, k, v, causal=causal, window=window, q_offset=q_offset,
                kv_positions=kv_positions, scale=scale,
            )
        return _ref.attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            kv_positions=kv_positions, scale=scale,
        )
    # kernel layout is (B, H, S, D)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(
        qt, kt, vt,
        causal=causal, window=window, q_offset=q_offset, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"),
    )
    return jnp.swapaxes(out, 1, 2)


# ------------------------------------------------------------------ SSD
def ssd(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    D: Optional[jax.Array] = None,
    chunk: int = 128,
    impl: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence SSD with zero initial state -> (y, final_state).

    Sequences are zero-padded up to a chunk multiple; padded steps carry
    dt=0 (decay factor exp(0)=1, zero input) so the final state is exact.
    """
    impl = _resolve(impl)
    S = x.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if impl == "ref":
        y, h = _ref.ssd_chunked_ref(
            x, dt, A, Bm, Cm, D, chunk=chunk, return_state=True
        )
    else:
        y, h = _ssd.ssd_chunked(
            x, dt, A, Bm, Cm, D, chunk=chunk, interpret=(impl == "interpret")
        )
    if pad:
        y = y[:, :S]
    return y, h


def ssd_decode_step(
    x: jax.Array,   # (B, 1, H, P)
    dt: jax.Array,  # (B, 1, H)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, 1, G, N)
    Cm: jax.Array,  # (B, 1, G, N)
    h: jax.Array,   # (B, H, P, N) carried state
    D: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Single-token state update (serve path); pure jnp, O(state)."""
    y, h_new = _ref.ssd_ref(x, dt, A, Bm, Cm, D, h0=h, return_state=True)
    return y, h_new
