"""Task Bench memory-bound kernel as a Pallas TPU kernel.

TPU adaptation of the paper's AVX2 streaming kernel: the scratch array lives
in HBM; the grid walks its windows, each program stages one ``span``-sized
window in VMEM and applies its share of the read-scale-write iterations.
The working set (``scratch_bytes``) stays constant as iterations shrink —
the paper's guard against cache-effect speedups (§II); on TPU the analogous
hazard is a working set that suddenly fits VMEM.

The sequential window walk (k = 0..iters-1 touching window k % nwin) is
reordered per-window: window w receives iterations {k : k % nwin == w},
which commute because windows are disjoint — results are bitwise equal to
the reference order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bodies import memory_step


def _memory_kernel(x_ref, o_ref, *, reps_base: int, reps_rem: int):
    w = pl.program_id(0)
    reps = reps_base + (w < reps_rem).astype(jnp.int32)
    win = x_ref[...]
    o_ref[...] = jax.lax.fori_loop(0, reps, lambda _, a: memory_step(a), win)


def taskbench_memory(
    x: jax.Array,  # (size,) f32 scratch, size % span == 0
    iterations: int,
    span: int,
    interpret: bool = False,
) -> jax.Array:
    size = x.shape[0]
    assert size % span == 0, (size, span)
    nwin = size // span
    return pl.pallas_call(
        functools.partial(
            _memory_kernel,
            reps_base=iterations // nwin,
            reps_rem=iterations % nwin,
        ),
        grid=(nwin,),
        in_specs=[pl.BlockSpec((span,), lambda w: (w,))],
        out_specs=pl.BlockSpec((span,), lambda w: (w,)),
        out_shape=jax.ShapeDtypeStruct((size,), jnp.float32),
        interpret=interpret,
    )(x)
