"""Task Bench compute-bound kernel as a Pallas TPU kernel.

TPU adaptation of paper Listing 1 (64-wide AVX2 FMA loop): each task owns
one (8, 128) float32 tile — a single TPU vector register — and performs one
fused multiply-add per element per iteration on the VPU.  Tiles for a block
of task columns are staged in VMEM; the grid walks column blocks.

Per-column iteration counts support the paper's load-imbalance studies; the
loop is masked exactly like the XLA reference so results match bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.kernel_spec import COMPUTE_TILE
from .bodies import compute_step, masked_loop


def _compute_kernel(iters_ref, tiles_ref, out_ref, *, max_iters: int):
    tiles = tiles_ref[...]  # (Wb, 8, 128) f32, VMEM
    iters = iters_ref[...]  # (Wb,) int32
    out_ref[...] = masked_loop(lambda k, a: compute_step(a), tiles, iters,
                               max_iters)


def taskbench_compute(
    tiles: jax.Array,  # (W, 8, 128) f32 initial tiles
    iters: jax.Array,  # (W,) int32 per-column iteration counts
    max_iters: int,
    block_cols: int = 8,
    interpret: bool = False,
) -> jax.Array:
    W = tiles.shape[0]
    assert tiles.shape[1:] == COMPUTE_TILE, tiles.shape
    block_cols = min(block_cols, W)
    assert W % block_cols == 0, (W, block_cols)
    grid = (W // block_cols,)
    return pl.pallas_call(
        functools.partial(_compute_kernel, max_iters=max_iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_cols,), lambda i: (i,)),
            pl.BlockSpec((block_cols,) + COMPUTE_TILE, lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_cols,) + COMPUTE_TILE, lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(tiles.shape, jnp.float32),
        interpret=interpret,
    )(iters, tiles)
