"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert allclose against these.  They are also the XLA path the models use
on non-TPU platforms (and what the multi-pod dry-run lowers).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.kernel_ref import MEM_BIAS, MEM_SCALE

NEG_INF = -1e30


# ---------------------------------------------------------------- taskbench
def taskbench_compute_ref(tiles: jax.Array, iterations: int) -> jax.Array:
    """(W, 8, 128) f32 tiles -> same, after `iterations` of a*a - a."""

    def step(_, a):
        return a * a - a

    return jax.lax.fori_loop(0, iterations, step, tiles)


def taskbench_memory_ref(x: jax.Array, iterations: int, span: int) -> jax.Array:
    """(size,) f32 scratch; window k%nwin updated per iteration."""
    size = x.shape[0]
    assert size % span == 0
    nwin = size // span

    def step(k, st):
        w = (k % nwin) * span
        win = jax.lax.dynamic_slice(st, (w,), (span,))
        return jax.lax.dynamic_update_slice(st, win * MEM_SCALE + MEM_BIAS, (w,))

    return jax.lax.fori_loop(0, iterations, step, x)


# ------------------------------------------------------------ attention
def attention_ref(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, D)
    causal: bool = True,
    window: Optional[int] = None,  # sliding window size (None = full)
    q_offset=0,  # absolute position of q[0]; int, traced scalar, or (B,)
    kv_positions: Optional[jax.Array] = None,  # (Skv,) or (B, Skv) positions
    scale: Optional[float] = None,
) -> jax.Array:
    """Grouped-query softmax attention oracle, fp32 accumulation.

    ``window=w`` allows key j for query i iff i - w < j <= i (Mistral SWA).
    ``kv_positions`` supports ring-buffer caches: keys carry arbitrary
    absolute positions; negative positions are treated as invalid slots.

    Both ``q_offset`` and ``kv_positions`` accept a leading batch dim —
    the serving engine's slot-granular decode runs every batch slot at its
    own depth, and left-padded wave prefills give each slot its own start
    offset (pad keys land at negative positions and are masked out).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    qs = q * jnp.asarray(scale, q.dtype)
    # mask is (Bm, Sq, Skv) with Bm in {1, B}: per-batch offsets/positions
    # broadcast against the shared causal structure
    qo = jnp.asarray(q_offset)
    qpos = jnp.arange(Sq)[None, :, None] + (
        qo[:, None, None] if qo.ndim == 1 else qo)
    if kv_positions is None:
        kpos = jnp.arange(Skv)[None, None, :]
    else:
        kvp = jnp.asarray(kv_positions)
        kpos = kvp[None, None, :] if kvp.ndim == 1 else kvp[:, None, :]
    mask = kpos >= 0
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)

    # Two GQA layouts (§Perf log):
    #  * decode (Sq==1): grouped einsum over un-repeated K/V — an 8x repeat
    #    of a 32k-token cache would dominate decode memory; heads stay
    #    replicated in decode (the cache is sequence-sharded), so the
    #    (Hkv, group) split costs nothing.
    #  * train/prefill: bf16 repeat to full heads.  The repeat fuses into
    #    the dot and keeps the head dim shardable over `model` — the
    #    grouped layout would split H into (Hkv, group), neither of which
    #    divides the mesh, forcing the partitioner to replicate fp32
    #    logits (measured: ~70% of the baseline collective bytes).
    # fp32 accumulation happens inside the dots; softmax stays fp32.
    if Sq == 1:
        qg = qs.reshape(B, Sq, Hkv, group, D)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=jnp.float32)
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, Sq, Hq, D).astype(q.dtype)

    kf = jnp.repeat(k, group, axis=2)
    vf = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qs, kf,
                        preferred_element_type=jnp.float32)
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), vf,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attention_ref_chunked(
    q, k, v, causal=True, window=None, q_offset=0, kv_positions=None,
    scale=None, q_chunk: int = 1024,
):
    """Memory-bounded oracle: sequential map over query chunks.

    Peak logits footprint is (B, H, q_chunk, Skv) instead of (B, H, Sq, Skv)
    — the XLA-path answer to 32k+ prefills (the Pallas kernel handles this
    by tiling on TPU; inference-only, so no scan-residual blowup).
    """
    B, Sq, Hq, D = q.shape
    q_chunk = min(q_chunk, Sq)
    if Sq % q_chunk:
        q_chunk = Sq  # irregular sizes: fall back to one chunk
    nq = Sq // q_chunk
    qs = q.reshape(B, nq, q_chunk, Hq, D)

    def one(i):
        return attention_ref(
            qs[:, i], k, v, causal=causal, window=window,
            q_offset=q_offset + i * q_chunk, kv_positions=kv_positions,
            scale=scale,
        )

    out = jax.lax.map(one, jnp.arange(nq))  # (nq, B, qc, H, D)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, D)


# ------------------------------------------------------------------- SSD
def ssd_ref(
    x: jax.Array,   # (B, S, H, P)   inputs (already multiplied by nothing)
    dt: jax.Array,  # (B, S, H)      softplus'd step sizes, > 0
    A: jax.Array,   # (H,)           negative decay rates
    Bm: jax.Array,  # (B, S, G, N)   input projections (groups like GQA)
    Cm: jax.Array,  # (B, S, G, N)   output projections
    D: Optional[jax.Array] = None,  # (H,) skip
    h0: Optional[jax.Array] = None,  # (B, H, P, N) initial state
    return_state: bool = False,
):
    """Mamba-2 SSD oracle: sequential scan over time, fp32 state.

    h_t = exp(dt_t A) h_{t-1} + dt_t * (x_t outer B_t);  y_t = C_t . h_t + D x_t
    """
    Bsz, S, H, Pdim = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B,S,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, Pdim, N), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        da = jnp.exp(dtt * Af[None])  # (B,H)
        dbx = jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], bt)
        h = da[..., None, None] * h + dbx
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, hT
    return y


def ssd_chunked_ref(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    D: Optional[jax.Array] = None, chunk: int = 64,
    h0: Optional[jax.Array] = None, return_state: bool = False,
):
    """Matmul-form chunked SSD (the algorithm the Pallas kernel implements).

    Splits S into chunks; intra-chunk contribution is a masked matmul
    (MXU-friendly), inter-chunk state is a short scan over chunk summaries.
    Mathematically identical to ssd_ref (same fp32 accumulation).
    """
    Bsz, S, H, Pdim = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    assert S % chunk == 0
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, Pdim)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2).reshape(Bsz, nc, chunk, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2).reshape(Bsz, nc, chunk, H, N)

    # per-position log decay within chunk: a_t = dt_t * A  (negative)
    la = dtf * Af[None, None, None]  # (B,nc,Q,H)
    cum = jnp.cumsum(la, axis=2)  # inclusive cumsum over chunk positions

    # intra-chunk: y_i += sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Cf, Bf)
    scores = cb * decay  # (B,nc,Qi,Qj,H)
    xdt = xf * dtf[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

    # chunk state summaries: S_c = sum_j exp(cum_last - cum_j) dt_j B_j^T x_j
    last = cum[:, :, -1:, :]  # (B,nc,1,H)
    tail = jnp.exp(last - cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcjhn,bcjhp->bchpn", Bf * (tail * dtf)[..., None], xf)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (B,nc,H)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, Pdim, N), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    def step(h, inp):
        s_c, d_c = inp  # (B,H,P,N), (B,H)
        h_in = h  # state entering this chunk
        h = d_c[..., None, None] * h + s_c
        return h, h_in

    hT, h_ins = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_ins = jnp.moveaxis(h_ins, 0, 1)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk output: y_i += C_i exp(cum_i) h_in
    inter_decay = jnp.exp(cum)  # (B,nc,Q,H)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Cf * inter_decay[..., None], h_ins)

    y = (y_intra + y_inter).reshape(Bsz, S, H, Pdim)
    if D is not None:
        y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, hT
    return y
