"""Sharded, elastic, async checkpointing.

Format: one ``step_<N>/`` directory per checkpoint holding
  manifest.json  — step, flat key list, shapes/dtypes, mesh metadata
  host<k>.npz    — this host's param/optimizer shards (single host: host0)

Elastic restore: arrays are loaded host-side and ``device_put`` against the
*current* mesh's NamedShardings — restoring onto a different mesh shape
(fewer/more pods after a failure) re-shards transparently.  Restore is
bit-exact: the fault-tolerance test kills a training run mid-stream and
verifies the resumed run reproduces the uninterrupted run's losses.

Writes are asynchronous (background thread) with an atomic rename commit;
``latest_step`` only trusts committed checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


# numpy's npz cannot round-trip extension dtypes (bfloat16 et al.); store
# them as equal-width integer views and reconstruct from the manifest.
_VIEW_FOR = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _to_npz(a: np.ndarray) -> np.ndarray:
    name = a.dtype.name
    if name in _VIEW_FOR:
        return a.view(_VIEW_FOR[name])
    return a


def _from_npz(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_FOR:
        import ml_dtypes

        return a.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return a


def save(ckpt_dir: str, step: int, tree, host_id: int = 0,
         async_write: bool = True) -> threading.Thread:
    """Write checkpoint for ``step``; returns the writer thread."""
    flat = _flatten(tree)
    # pull to host before handing to the writer thread
    host = [np.asarray(leaf) for _, leaf in flat]
    manifest = {
        "step": int(step),
        "keys": [k for k, _ in flat],
        "shapes": [list(a.shape) for a in host],
        "dtypes": [a.dtype.name for a in host],
        "num_hosts": 1,
    }
    arrays = {f"a{i}": _to_npz(a) for i, a in enumerate(host)}

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{host_id}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"host{host_id}.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit

    t = threading.Thread(target=_write, daemon=False)
    t.start()
    if not async_write:
        t.join()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, shardings=None):
    """Load checkpoint into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings for the
    *current* mesh — elastic re-sharding happens in device_put.
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "host0.npz"))
    flat_like = _flatten(tree_like)
    keys = manifest["keys"]
    assert [k for k, _ in flat_like] == keys, "checkpoint/tree structure mismatch"
    arrays = [_from_npz(data[f"a{i}"], manifest["dtypes"][i])
              for i in range(len(keys))]
    treedef = jax.tree_util.tree_structure(tree_like)
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        arrays = [jax.device_put(np.asarray(a)) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays)
