"""Self-validating reference execution (paper §II).

``execute_reference`` runs a task graph with the pure-numpy task body,
asserting at every task that the received payloads identify the expected
dependencies.  Backends are validated by comparing their final-timestep
outputs against this oracle: checksum/coordinate slots bitwise, kernel
slots with tolerance (matmul reduction order is backend-dependent).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .graph import TaskGraph


def execute_reference(graph: TaskGraph, return_all: bool = False):
    """Run the graph with the numpy task body, validating every input.

    Returns the final-timestep payload array [width, payload_elems]
    (or the full [height, width, payload_elems] history if return_all).
    """
    store: Dict[Tuple[int, int], np.ndarray] = {}
    hist = []
    for t in range(graph.height):
        row = []
        for i in range(graph.width):
            inputs = [store[(t - 1, j)] for j in graph.deps(t, i)]
            row.append(graph.execute_point(t, i, inputs))
        for i in range(graph.width):
            store[(t, i)] = row[i]
        if return_all:
            hist.append(np.stack(row))
        # free old timestep (only t-1 is ever read)
        for i in range(graph.width):
            store.pop((t - 2, i), None)
    if return_all:
        return np.stack(hist)
    return np.stack([store[(graph.height - 1, i)] for i in range(graph.width)])


def check_outputs(
    graph: TaskGraph,
    got: np.ndarray,
    expected: np.ndarray | None = None,
    kernel_rtol: float = 1e-5,
) -> None:
    """Assert a backend's final outputs match the oracle.

    Slots 0..3 (t, i, checksum, combined checksum) must match exactly;
    slot 4+ (kernel result and ballast) within ``kernel_rtol``.
    """
    if expected is None:
        expected = execute_reference(graph)
    got = np.asarray(got, dtype=np.float32)
    assert got.shape == expected.shape, (got.shape, expected.shape)
    exact_slots = got[:, :4], expected[:, :4]
    if not (exact_slots[0] == exact_slots[1]).all():
        bad = np.argwhere(exact_slots[0] != exact_slots[1])
        t0, s0 = bad[0]
        raise AssertionError(
            f"validation failed at column {t0} slot {s0}: "
            f"got {exact_slots[0][t0, s0]}, expected {exact_slots[1][t0, s0]} "
            f"(graph pattern={graph.pattern} kernel={graph.kernel.kind})"
        )
    np.testing.assert_allclose(
        got[:, 4:], expected[:, 4:], rtol=kernel_rtol, atol=1e-6,
        err_msg=f"kernel slots diverged (pattern={graph.pattern})",
    )


def check_multi(graphs: Sequence[TaskGraph], outputs: Sequence[np.ndarray], **kw) -> None:
    assert len(graphs) == len(outputs)
    for g, o in zip(graphs, outputs):
        check_outputs(g, o, **kw)
