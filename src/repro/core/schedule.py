"""Wavefront scheduling models: static column ownership vs work stealing.

The paper's load-imbalance study (§V-G) separates runtimes by *how tasks
are laid over workers*: statically-partitioned systems (MPI ranks, BSP)
pay the slowest worker's column block every wavefront, while dynamically-
scheduled systems (work stealing, task pools) re-pack a wavefront's tasks
greedily and recover most of the imbalance.

This module is the pure (numpy-only) form of both policies, shared by

* ``backends.host.HostBackend`` (``schedule="steal"``) — the *claim
  order* a work-stealing executor dispatches a wavefront in, and
* ``bench.timers.SyntheticTimer`` (``workers > 1``) — the deterministic
  per-wavefront makespan the fake clock charges for each policy,

so the executor and the timing model cannot drift apart.

Policies
--------

``"serial"``   one worker: makespan = sum of task costs.
``"static"``   columns blocked over workers exactly like
               ``dist.collectives`` blocks them over ranks (each worker
               owns ``ceil(n / workers)`` consecutive columns); makespan
               is the slowest worker's block sum.
``"steal"``    greedy claiming: whenever a worker goes idle it claims the
               longest unclaimed task of the wavefront (LPT list
               scheduling); makespan is the last worker's finish time.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

POLICIES = ("serial", "static", "steal")


def static_owners(ncols: int, workers: int) -> np.ndarray:
    """Worker id owning each column under blocked static partitioning.

    Matches the comm-plan layout: worker ``w`` owns columns
    ``[w * local, (w + 1) * local)`` with ``local = ceil(ncols/workers)``.
    """
    if ncols < 1 or workers < 1:
        raise ValueError(f"need ncols >= 1 and workers >= 1, "
                         f"got {ncols}, {workers}")
    local = -(-ncols // workers)
    return np.arange(ncols) // local


def steal_schedule(costs, workers: int) -> Tuple[List[int], np.ndarray, float]:
    """Greedy (LPT) claim schedule for one wavefront.

    Returns ``(order, start, makespan)``: ``order`` is the task-index
    sequence in claim order (ties broken by column id — deterministic),
    ``start`` the per-task start time, ``makespan`` the last finish.
    Each task appears in ``order`` exactly once.
    """
    costs = np.asarray(costs, dtype=np.float64)
    if costs.ndim != 1 or costs.size < 1:
        raise ValueError("costs must be a non-empty 1-D array")
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    # longest task first; lexsort's last key dominates, so sort by
    # (-cost, column) for a deterministic claim sequence
    claim = np.lexsort((np.arange(costs.size), -costs))
    free = np.zeros(workers, dtype=np.float64)
    start = np.empty(costs.size, dtype=np.float64)
    for i in claim:
        w = int(np.argmin(free))
        start[i] = free[w]
        free[w] += costs[i]
    order = [int(i) for i in claim]
    return order, start, float(free.max())


def wavefront_makespan(costs, workers: int, policy: str) -> float:
    """Seconds one wavefront takes under ``policy`` with ``workers``."""
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
    costs = np.asarray(costs, dtype=np.float64)
    if workers <= 1 or policy == "serial":
        return float(costs.sum())
    if policy == "static":
        owners = static_owners(costs.size, workers)
        return float(max(costs[owners == w].sum()
                         for w in range(workers)))
    return steal_schedule(costs, workers)[2]
