"""Task Bench core: the paper's primary contribution.

- graph: 2-D iteration space + dependence relation + self-validating body
- patterns: trivial/stencil/fft/sweep/tree/random/nearest/spread relations
- kernel_spec / kernel_ref: compute- and memory-bound task kernels
- metg: minimum-effective-task-granularity metric (paper §IV) —
  re-exported from ``repro.bench.metg``, where measurement now lives
- schedule: wavefront scheduling models (static ownership vs work
  stealing), shared by the host executor and the synthetic fake clock
- validate: numpy oracle executor + backend output checks
"""
from .graph import CHECKSUM_MOD, TaskGraph, make_graph, replicate
from .kernel_spec import KernelSpec
from .metg import METGResult, SweepPoint, compute_metg, geometric_iterations, run_sweep
from .patterns import get_pattern, pattern_names
from .schedule import static_owners, steal_schedule, wavefront_makespan
from .validate import check_multi, check_outputs, execute_reference

__all__ = [
    "CHECKSUM_MOD",
    "TaskGraph",
    "make_graph",
    "replicate",
    "KernelSpec",
    "METGResult",
    "SweepPoint",
    "compute_metg",
    "geometric_iterations",
    "run_sweep",
    "get_pattern",
    "pattern_names",
    "static_owners",
    "steal_schedule",
    "wavefront_makespan",
    "check_multi",
    "check_outputs",
    "execute_reference",
]
