"""METG metric — compatibility re-export.

The implementation moved to ``repro.bench.metg`` when measurement became a
first-class subsystem (``repro.bench``): the metric math is pure and the
harness around it (scenarios, timers, artifacts) lives with it.  This
module keeps the historical ``repro.core.metg`` / ``repro.core`` import
surface working unchanged.
"""
from __future__ import annotations

from ..bench.metg import (METGResult, SweepPoint, compute_metg,
                          efficiency_curve, geometric_iterations, run_sweep,
                          time_run)

__all__ = [
    "METGResult",
    "SweepPoint",
    "compute_metg",
    "efficiency_curve",
    "geometric_iterations",
    "run_sweep",
    "time_run",
]
