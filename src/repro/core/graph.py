"""Task graphs: the paper's 2-D iteration space + dependence relation.

The core API mirrors paper Table 3::

    Graph.contains_point(t, i)   -- is task (t, i) in the graph?
    Graph.deps(t, i)             -- predecessors of (t, i) (in timestep t-1)
    Graph.reverse_deps(t, i)     -- successors of (t, i) (in timestep t+1)
    Graph.execute_point(t, i, inputs) -- reference task body (numpy)

Task payloads are float32 vectors of ``payload_elems`` entries:

    payload[0] = t, payload[1] = i        (self-identification, paper §II)
    payload[2] = checksum(t, i)           (locally verifiable by consumers)
    payload[3] = combined history checksum (base + sum of dep slot-3 values)
    payload[4] = kernel result            (proves work was done)
    payload[5:] = kernel result broadcast (communication ballast)

Checksums are exact in float32 (kept < 2^20), so every backend must
reproduce them bit-for-bit; the kernel-result slot is compared with a small
tolerance (matmul reduction order may differ between backends).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .kernel_spec import KernelSpec
from .patterns import PatternInstance, get_pattern

CHECKSUM_MOD = 1 << 20  # keep exact in f32
MIN_PAYLOAD_ELEMS = 5


def _imbalance_u(t: int, i: int, seed: int) -> float:
    """Deterministic uniform in [0,1) per task (paper §V-G)."""
    import hashlib

    h = hashlib.blake2b(f"imb:{seed}:{t}:{i}".encode(), digest_size=8).digest()
    return int.from_bytes(h, "little") / 2.0**64


@dataclass(frozen=True)
class TaskGraph:
    """One parameterized task graph (paper Table 1)."""

    width: int = 16
    height: int = 32
    pattern: str = "stencil"
    pattern_params: Tuple[Tuple[str, object], ...] = ()
    kernel: KernelSpec = field(default_factory=KernelSpec)
    output_bytes: int = 16  # bytes per dependency payload

    def __post_init__(self):
        if self.width < 1 or self.height < 1:
            raise ValueError("width and height must be >= 1")
        object.__setattr__(self, "_pat", get_pattern(self.pattern, **dict(self.pattern_params)))

    # -- core API (paper Table 3) -------------------------------------------
    def contains_point(self, t: int, i: int) -> bool:
        return 0 <= t < self.height and 0 <= i < self.width

    def deps(self, t: int, i: int) -> List[int]:
        if not self.contains_point(t, i):
            return []
        return self._pat.deps(t, i, self.width)

    def reverse_deps(self, t: int, i: int) -> List[int]:
        if not self.contains_point(t, i):
            return []
        return self._pat.reverse_deps(t, i, self.width, self.height)

    def dependence_matrix(self, t: int) -> np.ndarray:
        """bool[width, width]: M[i, j] iff (t, i) depends on (t-1, j)."""
        return self._pat.matrix(t, self.width)

    def dependence_matrices(self) -> np.ndarray:
        """Stacked matrices for all timesteps: bool[height, width, width].

        Time-invariant patterns produce identical slices; backends may
        collapse them (the dataflow backend checks this to enable scan reuse).
        Cached on the (frozen) graph: comm planning, invariance checks and
        backend prepare all consume the same stack.
        """
        cached = self.__dict__.get("_mats_cache")
        if cached is None:
            cached = np.stack(
                [self.dependence_matrix(t) for t in range(self.height)])
            cached.setflags(write=False)
            object.__setattr__(self, "_mats_cache", cached)
        return cached

    def dependency_table(self, radix: Optional[int] = None):
        """Dense device-resident dependence form: padded index + mask.

        Returns ``(idx, mask)`` of shape ``(height, width, R)`` with
        ``R = max(1, max_radix())`` (or the requested ``radix >= R`` when
        stacking graphs of different patterns into one program): row
        ``(t, i)`` lists ``deps(t, i)`` in sorted column order, padded
        with column 0 under mask 0 — the ragged-padding idiom of
        ``dist.collectives``.  ``idx`` is int32, ``mask`` uint8; both
        read-only and cached on the (frozen) graph.  The megakernel
        backend indexes these in-kernel instead of consuming Python-side
        dependency lists or the dense (W, W) matrices.
        """
        cached = self.__dict__.get("_deptab_cache")
        if cached is None:
            r0 = max(1, self.max_radix())
            rows = [self._pat.index_table(t, self.width, r0)
                    for t in range(self.height)]
            idx = np.stack([r[0] for r in rows])
            mask = np.stack([r[1] for r in rows])
            idx.setflags(write=False)
            mask.setflags(write=False)
            cached = (idx, mask)
            object.__setattr__(self, "_deptab_cache", cached)
        idx, mask = cached
        r0 = idx.shape[2]
        if radix is None or radix == r0:
            return idx, mask
        if radix < r0:
            raise ValueError(f"requested radix {radix} < max radix {r0}")
        pad = ((0, 0), (0, 0), (0, radix - r0))
        return np.pad(idx, pad), np.pad(mask, pad)

    def is_time_invariant(self) -> bool:
        cached = self.__dict__.get("_invariant_cache")
        if cached is None:
            ms = self.dependence_matrices()[1:]
            cached = bool(ms.size == 0 or (ms == ms[0]).all())
            object.__setattr__(self, "_invariant_cache", cached)
        return cached

    # -- payloads ------------------------------------------------------------
    @property
    def payload_elems(self) -> int:
        return max(MIN_PAYLOAD_ELEMS, self.output_bytes // 4)

    def task_iterations(self, t: int, i: int) -> int:
        """Per-task duration after imbalance scaling.

        Rounding bound (pinned by the conservation property test): the
        returned count is within 0.5 of the analytic
        ``max(1, iterations * (1 - imbalance * u(t, i)))``, so the graph
        total is conserved within ``num_tasks / 2`` of the analytic sum,
        and every task stays in ``[1, iterations]``.
        """
        k = self.kernel
        if k.imbalance <= 0.0:
            return k.iterations
        u = _imbalance_u(t, i, k.seed)
        return max(1, int(round(k.iterations * (1.0 - k.imbalance * u))))

    def max_radix(self) -> int:
        return self._pat.max_radix(self.width, self.height)

    # -- reference task body (numpy oracle) ----------------------------------
    def checksum(self, t: int, i: int) -> int:
        """uint32 wrap-around hash of coordinates, reduced mod 2^20.

        Written so the identical arithmetic is exact both in python ints and
        in jnp.uint32 (backends) and in float32 payload slots (< 2^20).
        """
        return ((t * 2654435761 + i * 40503) % (1 << 32)) % CHECKSUM_MOD

    def checksum_table(self) -> np.ndarray:
        """All base checksums at once: uint32 ``(height, width)``.

        The megakernel precomputes these host-side — the wrap-around
        multiply needs uint32 arithmetic Mosaic cannot lower, while the
        values themselves (< 2^20) are exact in the kernel's int32 math.
        Cached read-only on the (frozen) graph.
        """
        cached = self.__dict__.get("_cktab_cache")
        if cached is None:
            t = np.arange(self.height, dtype=np.uint64)[:, None]
            i = np.arange(self.width, dtype=np.uint64)[None, :]
            cached = (((t * 2654435761 + i * 40503) % (1 << 32))
                      % CHECKSUM_MOD).astype(np.uint32)
            cached.setflags(write=False)
            object.__setattr__(self, "_cktab_cache", cached)
        return cached

    def execute_point(
        self, t: int, i: int, inputs: Sequence[np.ndarray]
    ) -> np.ndarray:
        """Reference (numpy) task body; validates inputs, runs kernel.

        ``inputs`` must be the payloads of ``deps(t, i)`` in sorted column
        order.  Raises AssertionError on validation failure (paper §II:
        'Inputs are verified by checking the expected dependencies against
        those received').
        """
        deps = self.deps(t, i)
        assert len(inputs) == len(deps), (
            f"task ({t},{i}) expected {len(deps)} inputs, got {len(inputs)}"
        )
        acc = 0
        for j, buf in zip(deps, inputs):
            assert int(buf[0]) == t - 1 and int(buf[1]) == j, (
                f"task ({t},{i}) received payload from "
                f"({int(buf[0])},{int(buf[1])}), expected ({t - 1},{j})"
            )
            expect = self.checksum(t - 1, j)
            assert int(buf[2]) == expect, (
                f"task ({t},{i}) dep ({t - 1},{j}) checksum {int(buf[2])}"
                f" != expected {expect}"
            )
            acc = (acc + int(buf[3])) % CHECKSUM_MOD
        result = self._run_kernel_ref(t, i)
        out = np.zeros(self.payload_elems, dtype=np.float32)
        out[0], out[1] = t, i
        out[2] = self.checksum(t, i)
        out[3] = (self.checksum(t, i) + acc) % CHECKSUM_MOD
        out[4] = result
        if self.payload_elems > 5:
            out[5:] = result
        return out

    def _run_kernel_ref(self, t: int, i: int) -> float:
        from . import kernel_ref

        return kernel_ref.run_kernel_ref(self.kernel, self.task_iterations(t, i))

    # -- convenience ----------------------------------------------------------
    def with_kernel(self, kernel: KernelSpec) -> "TaskGraph":
        return replace(self, kernel=kernel)

    def with_iterations(self, iterations: int) -> "TaskGraph":
        return replace(self, kernel=self.kernel.with_iterations(iterations))

    @property
    def num_tasks(self) -> int:
        return self.width * self.height

    def total_iterations(self) -> int:
        """Total kernel iterations across all tasks, imbalance-aware.

        The single definition of aggregate task duration, shared by the
        useful-work accounting below and the synthetic timing model
        (``repro.bench.timers.SyntheticTimer``).
        """
        k = self.kernel
        if k.imbalance <= 0:
            return k.iterations * self.num_tasks
        return sum(
            self.task_iterations(t, i)
            for t in range(self.height)
            for i in range(self.width)
        )

    def total_useful_work(self) -> float:
        """Total FLOPs (or bytes) across all tasks, imbalance-aware."""
        k = self.kernel
        per_iter = k.useful_work() / max(k.iterations, 1)
        return per_iter * self.total_iterations()


def make_graph(
    width: int = 16,
    height: int = 32,
    pattern: str = "stencil",
    kernel: str = "compute",
    iterations: int = 16,
    output_bytes: int = 16,
    imbalance: float = 0.0,
    span_bytes: int = 64 * 1024,
    scratch_bytes: int = 1 << 20,
    seed: int = 0,
    **pattern_params,
) -> TaskGraph:
    """Ergonomic constructor mirroring the paper's CLI parameters."""
    ks = KernelSpec(
        kind=kernel,
        iterations=iterations,
        imbalance=imbalance,
        span_bytes=span_bytes,
        scratch_bytes=scratch_bytes,
        seed=seed,
    )
    return TaskGraph(
        width=width,
        height=height,
        pattern=pattern,
        pattern_params=tuple(sorted(pattern_params.items())),
        kernel=ks,
        output_bytes=output_bytes,
    )


def replicate(graph: TaskGraph, n: int) -> List[TaskGraph]:
    """n identical concurrent graphs (paper Fig 9d: task parallelism)."""
    return [graph for _ in range(n)]
