"""Dependence relations for Task Bench task graphs (paper Table 2).

A dependence relation maps a point ``(t, i)`` in the 2-D iteration space
(``t`` = timestep, ``i`` = column) to the set of columns in timestep ``t-1``
that the task depends on.  Every pattern also provides a *matrix form*
``matrix(t, width) -> bool[width, width]`` with ``M[i, j] = True`` iff task
``(t, i)`` depends on ``(t-1, j)``; the vectorized backends consume this.

Patterns are registered by name so that graph configs are plain data.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence

import numpy as np

_REGISTRY: Dict[str, "DependencePattern"] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def pattern_names() -> List[str]:
    return sorted(_REGISTRY)


def get_pattern(name: str, **kwargs) -> "PatternInstance":
    if name not in _REGISTRY:
        raise KeyError(f"unknown dependence pattern {name!r}; known: {pattern_names()}")
    return PatternInstance(_REGISTRY[name], kwargs)


class DependencePattern:
    """Base class: stateless rules, parameterized at instantiation."""

    name = "base"

    @staticmethod
    def deps(t: int, i: int, width: int, **kw) -> List[int]:
        raise NotImplementedError

    @classmethod
    def matrix(cls, t: int, width: int, **kw) -> np.ndarray:
        m = np.zeros((width, width), dtype=bool)
        for i in range(width):
            for j in cls.deps(t, i, width, **kw):
                if 0 <= j < width:
                    m[i, j] = True
        return m


@dataclass(frozen=True)
class PatternInstance:
    """A pattern bound to its parameters (radix, fraction, seed...)."""

    rule: type
    params: dict

    @property
    def name(self) -> str:
        return self.rule.name

    def deps(self, t: int, i: int, width: int) -> List[int]:
        if t == 0:
            return []
        return sorted({j for j in self.rule.deps(t, i, width, **self.params) if 0 <= j < width})

    def reverse_deps(self, t: int, i: int, width: int, height: int) -> List[int]:
        """Successors of (t, i): columns k at t+1 with i in deps(t+1, k)."""
        if t + 1 >= height:
            return []
        return [k for k in range(width) if i in self.deps(t + 1, k, width)]

    def matrix(self, t: int, width: int) -> np.ndarray:
        if t == 0:
            return np.zeros((width, width), dtype=bool)
        return self.rule.matrix(t, width, **self.params)

    def max_radix(self, width: int, height: int) -> int:
        """Max #deps of any task — sizes CSP receive buffers."""
        r = 0
        for t in range(1, height):
            m = self.matrix(t, width)
            r = max(r, int(m.sum(axis=1).max(initial=0)))
        return r

    def index_table(self, t: int, width: int, radix: int):
        """Dense padded form of one timestep's dependence rows.

        Returns ``(idx, mask)`` of shape ``(width, radix)``: row ``i``
        holds ``deps(t, i)`` in sorted column order, padded with column 0
        under mask 0 (the ragged-padding idiom of ``dist.collectives``).
        ``idx`` is int32, ``mask`` uint8 — the device-resident form the
        megakernel indexes instead of Python-side dependency lists.
        """
        idx = np.zeros((width, radix), np.int32)
        mask = np.zeros((width, radix), np.uint8)
        for i in range(width):
            ds = self.deps(t, i, width)
            if len(ds) > radix:
                raise ValueError(
                    f"pattern {self.name!r} has {len(ds)} deps at "
                    f"({t},{i}) but the table radix is {radix}")
            idx[i, : len(ds)] = ds
            mask[i, : len(ds)] = 1
        return idx, mask


@register("trivial")
class Trivial(DependencePattern):
    """D(t,i) := {} — embarrassing parallelism."""

    @staticmethod
    def deps(t, i, width):
        return []


@register("no_comm")
class NoComm(DependencePattern):
    """D(t,i) := {i} — serial chains, no cross-column communication."""

    @staticmethod
    def deps(t, i, width):
        return [i]


@register("stencil")
class Stencil(DependencePattern):
    """D(t,i) := {i-1, i, i+1} — 1-D halo exchange."""

    @staticmethod
    def deps(t, i, width):
        return [i - 1, i, i + 1]


@register("sweep")
class Sweep(DependencePattern):
    """D(t,i) := {i-1, i} — wavefront, as in discrete-ordinates sweeps.

    This is also exactly the pipeline-parallel schedule dependence:
    stage i at clock t needs stage i-1's output of clock t-1 (the activation)
    and its own previous state.
    """

    @staticmethod
    def deps(t, i, width):
        return [i - 1, i]


@register("fft")
class FFT(DependencePattern):
    """D(t,i) := {i, i-2^t, i+2^t} — butterfly."""

    @staticmethod
    def deps(t, i, width):
        s = 2 ** (t - 1)  # timestep t consumes t-1; stride grows with level
        return [i, i - s, i + s]


@register("tree")
class Tree(DependencePattern):
    """Binary reduction tree followed by broadcast (paper Table 2).

    For t <= log2(width): column i receives from the pair it reduces.
    Afterwards: broadcast back down.
    """

    @staticmethod
    def deps(t, i, width):
        depth = max(1, int(np.log2(max(width, 2))))
        if t <= depth:
            stride = 2 ** (t - 1)
            group = 2 ** t
            if i % group == 0:
                return [i, i + stride]
            return []
        # broadcast phase: mirror of reduction
        bt = t - depth  # broadcast level
        group = 2 ** max(depth - bt, 0)
        src = (i // (group * 2)) * (group * 2) if group >= 1 else 0
        return [src, i] if i != src else [i]


@register("random")
class RandomPattern(DependencePattern):
    """D(t,i) := {j | random() < fraction} — deterministic per (t,i,j,seed)."""

    @staticmethod
    def _coin(t: int, i: int, j: int, seed: int) -> bool:
        h = hashlib.blake2b(
            f"{seed}:{t}:{i}:{j}".encode(), digest_size=8
        ).digest()
        return int.from_bytes(h, "little") % 1000 < 125  # fraction 1/8

    @staticmethod
    def deps(t, i, width, seed: int = 0):
        out = [j for j in range(width) if RandomPattern._coin(t, i, j, seed)]
        return out or [i]  # never fully disconnected


@register("nearest")
class Nearest(DependencePattern):
    """radix nearest neighbours centred on i (paper §V-C 'nearest').

    radix=0 -> no deps; radix=1 -> {i}; radix=3 -> {i-1,i,i+1}; radix=5 ->
    {i-2..i+2}; even radix skews left.
    """

    @staticmethod
    def deps(t, i, width, radix: int = 3):
        if radix <= 0:
            return []
        lo = i - radix // 2
        return [lo + k for k in range(radix)]


@register("spread")
class Spread(DependencePattern):
    """radix deps spread as widely as possible (paper §V-C 'spread')."""

    @staticmethod
    def deps(t, i, width, radix: int = 3):
        if radix <= 0:
            return []
        return [(i + k * width // radix + (t % max(1, width // max(radix, 1)))) % width
                for k in range(radix)]
