"""Reference (numpy) kernel bodies for the oracle executor.

These define the *semantics* each backend must reproduce.  Elementwise
kernels (compute, memory) are bitwise-reproducible in float32; the MXU
kernel involves a matmul whose reduction order differs across backends, so
its result slot is compared with tolerance (see validate.py).

The TPU adaptation of the paper's kernels (paper Listing 1):

* paper compute kernel: 64-wide AVX2 ``A = A*A + A`` -> here a (8,128) f32
  tile (one TPU vector register) iterating ``A = A*A - A`` (bounded orbit,
  still one FMA per element per iteration).
* paper memory kernel: sequential AVX2 read/write over a constant working
  set -> here a window walk over a scratch vector, constant working set as
  iterations shrink (paper §II).
"""
from __future__ import annotations

import numpy as np

from .kernel_spec import COMPUTE_TILE, MXU_DIM, KernelSpec

COMPUTE_START = np.float32(0.5)
# x <- x^2 - 1 from 0.5 falls onto the superstable {0, -1} 2-cycle:
# bounded (no overflow), never subnormal (a decaying orbit would hit the
# CPU denormal penalty and corrupt the FLOP/s baseline), and error-
# CONTRACTING (1-ulp FMA-contraction differences between backends are
# squashed instead of amplified — a chaotic orbit breaks reproducibility)
COMPUTE_C = np.float32(1.0)
MEM_SCALE = np.float32(1.0001)
MEM_BIAS = np.float32(1.0)


def mxu_weight() -> np.ndarray:
    """Deterministic small-valued 128x128 weight for the MXU kernel."""
    i = np.arange(MXU_DIM)
    w = ((np.add.outer(i * 131, i * 31) % 17).astype(np.float32) - 8.0) / 32.0
    return w


def run_kernel_ref(kernel: KernelSpec, iterations: int) -> float:
    if kernel.kind == "empty":
        return 0.0
    if kernel.kind == "compute":
        a = COMPUTE_START
        for _ in range(iterations):
            a = np.float32(a * a - COMPUTE_C)
        return float(a)
    if kernel.kind == "compute_mxu":
        b = np.full((MXU_DIM, MXU_DIM), 0.25, dtype=np.float32)
        w = mxu_weight()
        inv = np.float32(1.0 / MXU_DIM)
        for _ in range(iterations):
            b = (b @ w) * inv + b * np.float32(0.5)
        return float(b[0, 0])
    if kernel.kind == "memory":
        span = max(1, kernel.span_bytes // 4)
        size = max(span, kernel.scratch_bytes // 4)
        size -= size % span  # whole number of windows
        nwin = size // span
        x = np.full(size, 1.0, dtype=np.float32)
        for k in range(iterations):
            w = (k % nwin) * span
            x[w : w + span] = x[w : w + span] * MEM_SCALE + MEM_BIAS
        return float(x[0])
    raise ValueError(kernel.kind)
