"""Kernel specifications for Task Bench tasks (paper Table 1, §II).

A kernel is *what a task does*; the graph is *when it may do it*.  Kernels are
parameterized by ``iterations`` (task duration), plus kernel-specific knobs
(working-set size for the memory kernel, imbalance for load-imbalance
studies).  ``flops_per_task`` / ``bytes_per_task`` give the useful-work
measures that METG efficiency is computed against.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

# The TPU-native compute tile: one f32 vector register (8 sublanes x 128
# lanes).  The paper's AVX2 kernel uses 64 doubles; here one iteration is one
# fused multiply-add over the whole tile.
COMPUTE_TILE = (8, 128)
COMPUTE_TILE_ELEMS = COMPUTE_TILE[0] * COMPUTE_TILE[1]
FLOPS_PER_ELEM_PER_ITER = 2  # a*a + a -> one mul + one add

# MXU variant: one iteration is a 128x128 @ 128x128 matmul.
MXU_DIM = 128
MXU_FLOPS_PER_ITER = 2 * MXU_DIM**3


@dataclass(frozen=True)
class KernelSpec:
    kind: str = "compute"  # compute | memory | compute_mxu | empty
    iterations: int = 16
    # memory kernel: bytes touched per iteration and total working set
    span_bytes: int = 64 * 1024
    scratch_bytes: int = 4 * 1024 * 1024
    # imbalance: task duration multiplied by U[1-imbalance, 1] per task,
    # deterministic in (t, i, seed) -- paper §V-G uses U[0, 1).
    imbalance: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in ("compute", "compute_mxu", "memory", "empty"):
            raise ValueError(f"unknown kernel kind {self.kind!r}")
        if self.kind == "memory" and self.span_bytes > self.scratch_bytes:
            raise ValueError("span_bytes must be <= scratch_bytes")

    def with_iterations(self, iterations: int) -> "KernelSpec":
        return replace(self, iterations=iterations)

    @property
    def flops_per_task(self) -> float:
        if self.kind == "compute":
            return float(self.iterations * COMPUTE_TILE_ELEMS * FLOPS_PER_ELEM_PER_ITER)
        if self.kind == "compute_mxu":
            return float(self.iterations * MXU_FLOPS_PER_ITER)
        return 0.0

    @property
    def bytes_per_task(self) -> float:
        if self.kind == "memory":
            return float(self.iterations * self.span_bytes * 2)  # read + write
        return 0.0

    def useful_work(self) -> float:
        """The quantity efficiency is measured in (FLOPs or bytes)."""
        return self.flops_per_task if self.kind.startswith("compute") else self.bytes_per_task
