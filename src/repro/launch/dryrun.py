import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh from placeholder host
devices, constructs abstract inputs (ShapeDtypeStruct — no allocation),
lowers the real jitted step (train_step for train shapes, serve_step for
decode shapes, forward for prefill), compiles it, and records
``memory_analysis()`` / ``cost_analysis()`` / per-collective byte counts.

Results accumulate incrementally in a JSON cache (one entry per cell x
mesh x strategy) so interrupted sweeps resume; ``--force`` recomputes.

Usage:
  python -m repro.launch.dryrun                     # full sweep, both meshes
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --strategy dp_only  # naive baseline (§Perf)
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ALL_ARCHS, SHAPES, get_config, shape_applicable
from ..dist.sharding import make_rules, use_rules
from ..launch import specs as SP
from ..launch.mesh import make_production_mesh
from ..launch.roofline import analyze_hlo
from ..models import model as M
from ..optim import adamw
from ..serve.engine import serve_step
from ..train import train_step as TS

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "results", "dryrun.json")


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               strategy: str = "tp+fsdp+sp", overrides=None,
               accum: int = 0):
    """Returns a result dict for one cell (raises on lowering bugs)."""
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **{k: v for k, v in overrides.items()
                                          if hasattr(cfg, k)})
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, strategy=strategy)

    t0 = time.time()
    with mesh, use_rules(rules):
        if shape.kind == "train":
            accum = accum or SP.train_grad_accum(cfg, shape, mesh)
            tcfg = TS.TrainConfig(
                grad_accum=accum,
                adamw=adamw.AdamWConfig(
                    state_dtype=cfg.opt_state_dtype,
                    master_weights=(cfg.opt_state_dtype == "float32"),
                ),
            )
            state, state_axes = SP.state_struct(cfg, tcfg)
            state_sh = SP.shardings_from_axes(state_axes, state, rules)
            batch, batch_axes = SP.batch_struct(cfg, shape)
            batch_sh = SP.shardings_from_axes(batch_axes, batch, rules)
            fn = functools.partial(TS.train_step, cfg=cfg, tcfg=tcfg)
            jitted = jax.jit(fn, donate_argnums=(0,),
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state, batch)
            extra = {"grad_accum": accum}
        elif shape.kind == "prefill":
            params, axes = SP.params_struct(cfg)
            params_sh = SP.shardings_from_axes(axes, params, rules)
            batch, batch_axes = SP.batch_struct(cfg, shape)
            batch_sh = SP.shardings_from_axes(batch_axes, batch, rules)

            def prefill_fwd(p, b):
                logits, _, _ = M.forward(p, cfg, tokens=b.get("tokens"),
                                         embeds=b.get("embeds"),
                                         last_token_only=True)
                return jnp.argmax(logits[:, -1], axis=-1)

            jitted = jax.jit(prefill_fwd, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params, batch)
            extra = {}
        else:  # decode / long_decode: one new token against a full cache
            params, axes = SP.params_struct(cfg)
            params_sh = SP.shardings_from_axes(axes, params, rules)
            B = shape.global_batch
            caches, cache_axes = SP.caches_struct(cfg, B, shape.seq_len)
            if isinstance(caches, list):
                caches_sh = [SP.shardings_from_axes(a, c, rules)
                             for a, c in zip(cache_axes, caches)]
            else:  # stacked (scanned models): single LayerCache pytree
                caches_sh = SP.shardings_from_axes(cache_axes, caches, rules)
            toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            toks_sh = rules.sharding_for(("batch", None), (B, 1))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            fn = functools.partial(serve_step, cfg=cfg)
            jitted = jax.jit(fn, donate_argnums=(2,),
                             in_shardings=(params_sh, toks_sh, caches_sh, None),
                             out_shardings=(toks_sh, caches_sh))
            lowered = jitted.lower(params, toks, caches, pos)
            extra = {}

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    analysis = analyze_hlo(compiled.as_text())
    result = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_name(multi_pod),
        "strategy": strategy, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # loop-aware per-device totals (launch.roofline.analyze_hlo)
        "flops_per_device": analysis["flops"],
        "hbm_bytes_per_device": analysis["hbm_bytes"],
        "collectives": analysis["collectives"],
        "unknown_trip_whiles": analysis["unknown_trip_whiles"],
        # XLA's own (loop-unaware) numbers, for reference
        "xla_cost_flops": cost.get("flops", 0.0),
        "xla_cost_bytes": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
        },
        **extra,
    }
    return result


def cell_key(r) -> str:
    return f"{r['arch']}|{r['shape']}|{r['mesh']}|{r['strategy']}"


def load_results(path):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path, results):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ALL_ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="tp+fsdp+sp")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_PATH))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--accum", type=int, default=0,
                    help="override gradient-accumulation steps (train cells)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ALL_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = load_results(args.out)
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            runnable, why = shape_applicable(cfg, SHAPES[shape_name])
            for mp in meshes:
                key = f"{arch}|{shape_name}|{_mesh_name(mp)}|{args.strategy}"
                if key in results and not args.force \
                        and results[key].get("status") in ("ok", "skip"):
                    print(f"[cached] {key}")
                    continue
                if not runnable:
                    results[key] = {
                        "arch": arch, "shape": shape_name,
                        "mesh": _mesh_name(mp), "strategy": args.strategy,
                        "status": "skip", "reason": why,
                    }
                    save_results(args.out, results)
                    print(f"[skip]   {key}: {why}")
                    continue
                print(f"[lower]  {key} ...", flush=True)
                try:
                    r = lower_cell(arch, shape_name, mp, args.strategy,
                                   accum=args.accum)
                    results[key] = r
                    print(f"[ok]     {key}: compile {r['compile_s']}s "
                          f"args {r['memory']['argument_gb']:.2f}GB "
                          f"temp {r['memory']['temp_gb']:.2f}GB")
                except Exception as e:  # record the failure, keep sweeping
                    results[key] = {
                        "arch": arch, "shape": shape_name,
                        "mesh": _mesh_name(mp), "strategy": args.strategy,
                        "status": "error", "error": str(e)[:2000],
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    print(f"[FAIL]   {key}: {e}")
                save_results(args.out, results)


if __name__ == "__main__":
    main()
