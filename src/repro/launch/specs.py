"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``cell_specs(arch, shape, mesh)`` returns everything needed to lower a
cell without allocating a single byte: abstract train state / params /
batch / caches plus their NamedShardings (derived from the logical-axes
trees through the divisibility-fallback rules).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, InputShape, get_config
from ..dist.sharding import ShardingRules, make_rules
from ..models import model as M
from ..models.cache import cache_logical_axes, init_caches
from ..models.layers import split_leaves
from ..optim import adamw
from ..train import train_step as TS

AXES_LEAF = lambda x: isinstance(x, tuple) and all(
    isinstance(e, (str, type(None))) for e in x)


def shardings_from_axes(axes_tree, struct_tree, rules: ShardingRules):
    """logical-axes tree + abstract value tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda ax, s: rules.sharding_for(ax, s.shape),
        axes_tree, struct_tree, is_leaf=AXES_LEAF)


def batch_struct(cfg, shape: InputShape) -> Tuple[Dict, Dict]:
    """(struct, logical axes) for one training/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend:
        return (
            {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)},
            {"embeds": ("batch", "seq", None), "labels": ("batch", "seq")},
        )
    return (
        {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
         "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)},
        {"tokens": ("batch", "seq"), "labels": ("batch", "seq")},
    )


def state_struct(cfg, tcfg: TS.TrainConfig):
    """(abstract TrainState, logical-axes TrainState) via eval_shape."""
    def build(key):
        state, _ = TS.init_state(key, cfg, tcfg)
        return state

    state = jax.eval_shape(build, jax.random.PRNGKey(0))
    # rebuild the axes tree (host-side, cheap)
    leaf_tree = jax.eval_shape(
        functools.partial(M.init_model, cfg=cfg), jax.random.PRNGKey(0))
    _, axes = split_leaves(leaf_tree)
    axes_state = TS.TrainState(
        step=(),
        params=axes,
        opt=adamw.state_logical_axes(state.opt, axes),
    )
    return state, axes_state


def params_struct(cfg):
    leaf_tree = jax.eval_shape(
        functools.partial(M.init_model, cfg=cfg), jax.random.PRNGKey(0))
    return split_leaves(leaf_tree)


def caches_struct(cfg, batch: int, max_len: int):
    """(abstract caches, matching logical axes).

    Scanned homogeneous stacks get a single stacked LayerCache (leading
    layer dim, rides the decode scan carry — in-place updates, no unstack
    copies); heterogeneous stacks get the per-layer list."""
    caches = jax.eval_shape(
        lambda: init_caches(cfg, batch, max_len, dtype=jnp.bfloat16))
    if cfg.scan_layers and len(set(cfg.pattern_for_depth())) == 1:
        stacked = jax.eval_shape(
            lambda *cs: jax.tree.map(lambda *xs: jnp.stack(xs), *cs), *caches)
        ax = cache_logical_axes(caches[0])
        axes = jax.tree.map(
            lambda a: (None,) + tuple(a), ax,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        return stacked, axes
    axes = [cache_logical_axes(c) for c in caches]
    return caches, axes


def decode_grad_accum(cfg, shape: InputShape, mesh) -> int:
    return 1


def train_grad_accum(cfg, shape: InputShape, mesh) -> int:
    """Pick microbatching so per-device microbatch stays small (<=4)."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_loc = max(1, shape.global_batch // dp)
    return max(1, b_loc // 4)
