"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
only launch/dryrun.py sets the 512-placeholder-device XLA flag).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many local devices tests spawned."""
    return jax.make_mesh(shape, axes)
