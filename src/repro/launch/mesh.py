"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked at first jax init, and
only launch/dryrun.py sets the 512-placeholder-device XLA flag).
"""
from __future__ import annotations

from typing import Tuple

import jax


def production_mesh_spec(
    *, multi_pod: bool = False, pipeline_stages: int = 1,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """(shape, axes) of the production mesh, without touching devices.

    Base: 16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).
    ``pipeline_stages > 1`` grows a trailing ``stage`` axis carved out of
    the data axis (total chip count is preserved), giving the 4D
    ``(pod, data, model, stage)`` strategy that ``dist.pipeline`` and the
    ``shardmap-pipeline`` backend shard over.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if pipeline_stages <= 1:
        return shape, axes
    data = shape[-2]
    if data % pipeline_stages:
        raise ValueError(
            f"data axis {data} not divisible by {pipeline_stages} stages")
    shape = shape[:-2] + (data // pipeline_stages, shape[-1], pipeline_stages)
    return shape, axes + ("stage",)


def make_production_mesh(*, multi_pod: bool = False, pipeline_stages: int = 1):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips),
    optionally with a ``stage`` pipeline axis."""
    shape, axes = production_mesh_spec(
        multi_pod=multi_pod, pipeline_stages=pipeline_stages)
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many local devices tests spawned."""
    return jax.make_mesh(shape, axes)


def moe_dispatch_planes(mesh_shape, ep_mode: str) -> int:
    """How many identical copies of the MoE dispatch all-to-all run
    concurrently over the ``model`` axis.

    ``replicated`` tokens duplicate the dispatch per model plane
    (|model| copies of the same a2a); SP-aware EP (``ep_mode="sp"``)
    shards the sequence over ``model`` so each plane moves distinct rows
    — one logical dispatch, per-plane volume cut by |model|.  Used by the
    ``moe_dispatch`` roofline scenario (``repro.bench.moe``) to model
    comm volume without devices.  ``mesh_shape`` is any axis-name ->
    size mapping (``Mesh.shape`` or a plain dict).
    """
    if ep_mode not in ("replicated", "sp"):
        raise ValueError(
            f"unknown ep_mode {ep_mode!r}; known: ('replicated', 'sp')")
    return 1 if ep_mode == "sp" else int(dict(mesh_shape).get("model", 1))
