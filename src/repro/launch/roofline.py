"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (TPU v5e targets):

  compute    = per-device matmul FLOPs / 197 TF/s (bf16 peak)
  memory     = per-device HBM-boundary bytes / 819 GB/s
  collective = per-device collective bytes / 50 GB/s per ICI link

``compiled.cost_analysis()`` does NOT expand ``while`` loops (scan over
layers, gradient accumulation), so this module parses the optimized HLO
text directly and walks the call graph, multiplying every computation's
cost by the loop trip counts XLA annotates (``known_trip_count``):

  * FLOPs: every ``dot`` (2*result_elems*K from the operand symbol table;
    dots inside fusions included) and ``convolution`` (approximated from
    window size); elementwise FLOPs are ignored (documented: matmul
    roofline).
  * HBM bytes: sum of operand+result bytes of every top-level instruction
    that crosses the HBM boundary (fusion/dot/copy/reduce/...); fusion
    internals excluded (they live in VMEM/registers).
  * Collective bytes, per-device convention: all-gather/all-to-all/
    collective-permute = result bytes; all-reduce = 2x result
    (reduce-scatter + all-gather phases); reduce-scatter = operand bytes.

Also reported: MODEL_FLOPS = 6*N_active*D and its ratio to compiled HLO
FLOPs — the "useful compute" fraction exposing remat/redundancy waste.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# -------------------------------------------------- hardware constants
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\],{}\d ]+?))\s*"
    r"([\w\-]+)\(")


def _parse_computations(hlo: str):
    """-> (comps: name -> [Instr], entry_name)."""
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    header = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\{\s*$")
    entry = None
    for line in hlo.splitlines():
        if not line.startswith(" "):
            m = header.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(_Instr(m.group(1), m.group(2).strip(),
                                     m.group(3), line))
    return comps, entry


def _operands(instr: _Instr) -> List[str]:
    """Operand %names of an instruction line."""
    inner = instr.line.split(instr.op + "(", 1)[1]
    # cut at the matching close paren (operands never nest parens)
    depth, out, cur = 1, [], ""
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur += ch
    args = cur.split(",")
    names = []
    for a in args:
        a = a.strip()
        if a.startswith("%"):
            names.append(a[1:])
        else:
            m = re.search(r"%([\w\.\-]+)", a)
            if m:
                names.append(m.group(1))
    return names


def _dot_flops(instr: _Instr, symtab: Dict[str, str]) -> float:
    result_elems = 1
    for d in _shape_dims(instr.type_str):
        result_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    ops = _operands(instr)
    if not m or not ops or ops[0] not in symtab:
        return 2.0 * result_elems  # degenerate
    lhs_dims = _shape_dims(symtab[ops[0]])
    k = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            k *= lhs_dims[int(idx)]
    return 2.0 * result_elems * k


def _conv_flops(instr: _Instr, symtab: Dict[str, str]) -> float:
    result_elems = 1
    for d in _shape_dims(instr.type_str):
        result_elems *= d
    m = re.search(r"window=\{size=([\dx]+)", instr.line)
    window = 1
    if m:
        for d in m.group(1).split("x"):
            window *= int(d)
    # depthwise convs (feature_group_count=C) contract only the window
    ops = _operands(instr)
    in_feat = 1
    gm = re.search(r"feature_group_count=(\d+)", instr.line)
    groups = int(gm.group(1)) if gm else 1
    if len(ops) > 1 and ops[1] in symtab:
        kdims = _shape_dims(symtab[ops[1]])
        if len(kdims) >= 2:
            in_feat = kdims[-2]  # HIO layout: input features dim
    return 2.0 * result_elems * window * max(in_feat // max(groups, 1), 1)


@dataclass
class HLOCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    attn_sq_bytes: float = 0.0  # traffic of (.., S, S) attention tensors
    collectives: Dict[str, float] = field(default_factory=dict)

    def scaled(self, k: float) -> "HLOCost":
        return HLOCost(self.flops * k, self.hbm_bytes * k,
                       self.attn_sq_bytes * k,
                       {kk: v * k for kk, v in self.collectives.items()})

    def add(self, other: "HLOCost"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.attn_sq_bytes += other.attn_sq_bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v


def _is_attn_quadratic(type_str: str) -> bool:
    """rank>=3 tensor containing two equal dims >= 1024 — the (B, H, S, S)
    logits/probs family.  The Pallas flash-attention kernel keeps these in
    VMEM tiles; in the XLA reference lowering they cross HBM at every
    fusion boundary, so their traffic is reported separately."""
    dims = _shape_dims(type_str)
    if len(dims) < 3:
        return False
    big = [d for d in dims if d >= 1024]
    return any(big.count(d) >= 2 for d in set(big))


def _collective_kind(op: str) -> Optional[str]:
    base = op.replace("-start", "")
    return base if base in COLLECTIVE_KINDS else None


def analyze_hlo(hlo: str) -> Dict:
    """Loop-aware {flops, hbm_bytes, collectives{kind: bytes}, unknown_trips}."""
    comps, entry = _parse_computations(hlo)
    if entry is None:
        entry = next(iter(comps), None)
    symtabs = {
        name: {i.name: i.type_str for i in instrs}
        for name, instrs in comps.items()
    }
    # add parameter types (they match _INSTR_RE with op 'parameter')
    fusion_flops_memo: Dict[str, float] = {}
    unknown_trips = [0]

    def fusion_flops(name: str, depth=0) -> float:
        """dots inside fusion computations still hit the MXU."""
        if name in fusion_flops_memo:
            return fusion_flops_memo[name]
        if name not in comps or depth > 40:
            return 0.0
        fusion_flops_memo[name] = 0.0
        total = 0.0
        for i in comps[name]:
            if i.op == "dot":
                total += _dot_flops(i, symtabs[name])
            elif i.op == "convolution":
                total += _conv_flops(i, symtabs[name])
            elif i.op == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", i.line)
                if m:
                    total += fusion_flops(m.group(1), depth + 1)
        fusion_flops_memo[name] = total
        return total

    memo: Dict[str, HLOCost] = {}

    def visit(name: str, depth=0) -> HLOCost:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 60:
            return HLOCost()
        memo[name] = HLOCost()  # cycle guard
        acc = HLOCost()
        symtab = symtabs[name]
        for i in comps[name]:
            kind = _collective_kind(i.op)
            if kind:
                rbytes = _shape_bytes(i.type_str)
                if kind == "all-reduce":
                    cbytes = 2.0 * rbytes
                elif kind == "reduce-scatter":
                    cbytes = sum(_shape_bytes(symtab.get(o, ""))
                                 for o in _operands(i)) or rbytes
                else:
                    cbytes = rbytes
                acc.collectives[kind] = acc.collectives.get(kind, 0.0) + cbytes
                acc.hbm_bytes += rbytes
                continue
            if i.op == "dot":
                acc.flops += _dot_flops(i, symtab)
            elif i.op == "convolution":
                acc.flops += _conv_flops(i, symtab)
            elif i.op == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", i.line)
                if m:
                    acc.flops += fusion_flops(m.group(1))
            elif i.op == "while":
                trip = 1
                m = re.search(r'known_trip_count[^0-9]*(\d+)', i.line)
                if m:
                    trip = int(m.group(1))
                else:
                    unknown_trips[0] += 1
                bm = re.search(r"body=%([\w\.\-]+)", i.line)
                if bm:
                    acc.add(visit(bm.group(1), depth + 1).scaled(trip))
                continue
            elif i.op == "conditional":
                for m in re.finditer(
                        r"(?:true_computation|false_computation)=%([\w\.\-]+)",
                        i.line):
                    acc.add(visit(m.group(1), depth + 1))
                bm = re.search(r"branch_computations=\{([^}]*)\}", i.line)
                if bm:
                    for nm in bm.group(1).split(","):
                        acc.add(visit(nm.strip().lstrip("%"), depth + 1))
            elif i.op in ("call", "async-start"):
                m = re.search(r"(?:to_apply|called_computation)=%([\w\.\-]+)",
                              i.line)
                if m:
                    acc.add(visit(m.group(1), depth + 1))
            # HBM traffic: operands + result of boundary-crossing ops
            if i.op not in _NO_TRAFFIC_OPS:
                b = _shape_bytes(i.type_str)
                quad = _is_attn_quadratic(i.type_str)
                for o in _operands(i):
                    ts = symtab.get(o, "")
                    b += _shape_bytes(ts)
                    quad = quad or _is_attn_quadratic(ts)
                acc.hbm_bytes += b
                if quad:
                    acc.attn_sq_bytes += b
        memo[name] = acc
        return acc

    total = visit(entry) if entry else HLOCost()
    colls = dict(total.collectives)
    colls["total"] = float(sum(total.collectives.values()))
    return {
        "flops": float(total.flops),
        "hbm_bytes": float(total.hbm_bytes),
        "attn_sq_bytes": float(total.attn_sq_bytes),
        "collectives": colls,
        "unknown_trip_whiles": unknown_trips[0],
    }


def collective_bytes_by_kind(hlo: str) -> Dict[str, float]:
    return analyze_hlo(hlo)["collectives"]


# ------------------------------------------------------------- terms
def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the step: 6*N_active*tokens (train),
    2*N_active*tokens (prefill), 2*N_active*batch (decode)."""
    n_active = cfg.params_active
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def model_bytes(cfg, shape) -> float:
    """Useful HBM traffic for one decode step: every active parameter is
    read once (weights dominate batched decode) plus the KV/state cache."""
    param_bytes = 2.0 * cfg.params_active  # bf16
    cache = 0.0
    for kind in cfg.pattern_for_depth():
        if kind in ("attn", "moe"):
            w = cfg.window or shape.seq_len
        elif kind == "local_attn":
            w = cfg.local_window or shape.seq_len
        elif kind == "ssd":
            d_in = cfg.ssm_expand * cfg.d_model
            cache += (d_in // cfg.ssm_headdim) * cfg.ssm_headdim \
                * cfg.ssm_state * 4.0 * shape.global_batch
            continue
        elif kind == "rglru":
            cache += (cfg.lru_width or cfg.d_model) * 4.0 * shape.global_batch
            continue
        else:
            continue
        w = min(w, shape.seq_len)
        cache += (2 * w * cfg.num_kv_heads * cfg.head_dim * 2.0
                  * shape.global_batch)
    return param_bytes + cache


def roofline_terms(analysis: Dict, cfg, shape, chips: int) -> Dict:
    flops_dev = float(analysis.get("flops", 0.0))
    bytes_dev = float(analysis.get("hbm_bytes", 0.0))
    coll_dev = float(analysis.get("collectives", {}).get("total", 0.0))
    attn_sq = float(analysis.get("attn_sq_bytes", 0.0))
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    # on the TPU target the Pallas flash-attention kernel keeps the
    # (B,H,S,S) logits family in VMEM; the dry-run lowers the XLA
    # reference, so its quadratic traffic is removed from the memory term
    # (raw value still reported as memory_s_raw)
    memory_flash_s = max(bytes_dev - attn_sq, 0.0) / HBM_BW
    coll_s = coll_dev / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_flash_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * chips
    bound = max(terms.values())
    out = {
        **terms,
        "memory_s_raw": memory_s,
        "attn_sq_bytes": attn_sq,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "bound_step_s": bound,
        # fraction of the machine's peak the useful FLOPs achieve when the
        # step runs at its binding roofline term
        "roofline_fraction": (mf / bound / (chips * PEAK_FLOPS)
                              if bound > 0 else 0.0),
    }
    if shape.kind in ("decode", "long_decode"):
        # decode is bandwidth-limited by construction: score useful HBM
        # traffic (weights + cache, read once) against the machine's HBM
        ub = model_bytes(cfg, shape)
        out["useful_bytes"] = ub
        out["bw_fraction"] = (ub / bound / (chips * HBM_BW)
                              if bound > 0 else 0.0)
        out["roofline_fraction"] = out["bw_fraction"]
    return out
