"""Render EXPERIMENTS.md roofline/dry-run tables from results/dryrun.json."""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

from ..configs import SHAPES, get_config
from .roofline import HBM_BW, LINK_BW, PEAK_FLOPS, roofline_terms

CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


def fmt_si(x: float, unit: str = "") -> str:
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= div:
            return f"{x / div:.2f}{suf}{unit}"
    return f"{x:.2f}{unit}"


def row_terms(v: Dict) -> Dict:
    cfg = get_config(v["arch"])
    shape = SHAPES[v["shape"]]
    analysis = {
        "flops": v.get("flops_per_device", 0.0),
        "hbm_bytes": v.get("hbm_bytes_per_device", 0.0),
        "collectives": v.get("collectives", {"total": 0.0}),
    }
    return roofline_terms(analysis, cfg, shape, CHIPS[v["mesh"]])


def hbm_total_gb(v: Dict) -> float:
    m = v["memory"]
    return m["argument_gb"] + m["temp_gb"] + m["output_gb"] - m["alias_gb"]


def render_roofline_table(results: Dict, mesh: str = "pod16x16",
                          strategy: str = "tp+fsdp+sp") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " 6ND/HLO | roofline_frac | HBM GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        v = results[key]
        if v["mesh"] != mesh or v.get("strategy") != strategy:
            continue
        if v["status"] == "skip":
            lines.append(
                f"| {v['arch']} | {v['shape']} | — | — | — | skip |"
                f" — | — | — | ({v['reason']}) |")
            continue
        if v["status"] != "ok":
            lines.append(f"| {v['arch']} | {v['shape']} | ERROR |||||||{v.get('error','')[:40]}|")
            continue
        t = row_terms(v)
        gb = hbm_total_gb(v)
        fits = "yes" if gb <= 16.0 else f"**NO**"
        lines.append(
            f"| {v['arch']} | {v['shape']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant'].replace('_s','')} "
            f"| {t['useful_ratio']:.3f} | {t['roofline_fraction']*100:.2f}% "
            f"| {gb:.1f} | {fits} |")
    return "\n".join(lines)


def render_dryrun_table(results: Dict, strategy: str = "tp+fsdp+sp") -> str:
    lines = [
        "| arch | shape | mesh | compile_s | args GB | temp GB | alias GB |"
        " flops/dev | HLO bytes/dev | coll bytes/dev | a2a | ag | ar | rs | cp |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        v = results[key]
        if v.get("strategy") != strategy or v["status"] != "ok":
            continue
        m, c = v["memory"], v["collectives"]
        lines.append(
            f"| {v['arch']} | {v['shape']} | {v['mesh']} | {v['compile_s']} "
            f"| {m['argument_gb']:.2f} | {m['temp_gb']:.2f} "
            f"| {m['alias_gb']:.2f} | {fmt_si(v['flops_per_device'])} "
            f"| {fmt_si(v['hbm_bytes_per_device'])} | {fmt_si(c['total'])} "
            f"| {fmt_si(c.get('all-to-all', 0))} "
            f"| {fmt_si(c.get('all-gather', 0))} "
            f"| {fmt_si(c.get('all-reduce', 0))} "
            f"| {fmt_si(c.get('reduce-scatter', 0))} "
            f"| {fmt_si(c.get('collective-permute', 0))} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun.json")
    ap.add_argument("--mode", default="roofline",
                    choices=["roofline", "dryrun", "pick"])
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--strategy", default="tp+fsdp+sp")
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    if args.mode == "roofline":
        print(render_roofline_table(results, args.mesh, args.strategy))
    elif args.mode == "dryrun":
        print(render_dryrun_table(results, args.strategy))
    else:  # pick hillclimb candidates
        rows = []
        for key, v in results.items():
            if v["status"] != "ok" or v["mesh"] != args.mesh \
                    or v.get("strategy") != args.strategy:
                continue
            t = row_terms(v)
            rows.append((t["roofline_fraction"], key, t["dominant"],
                         t["collective_s"], hbm_total_gb(v)))
        rows.sort()
        print("worst roofline fractions:")
        for frac, key, dom, coll, gb in rows[:8]:
            print(f"  {frac*100:6.2f}%  {key}  dom={dom} coll={coll:.3f}s "
                  f"hbm={gb:.1f}GB")
        rows.sort(key=lambda r: -r[3])
        print("most collective-bound (seconds):")
        for frac, key, dom, coll, gb in rows[:8]:
            print(f"  {coll:8.3f}s {key}  frac={frac*100:.2f}%")


if __name__ == "__main__":
    main()
