"""The ``moe_dispatch`` scenario: MoE dispatch comm volume as a roofline.

Not a task-graph scenario — the "graph" is one MoE layer's token dispatch
— but it is measured the same dry-run way as ``DryRunTimer``: lower the
compiled program, walk the optimized HLO with
``launch.roofline.analyze_hlo``, and report collective bytes and the
interconnect roofline term.  Two paths:

* **analytic** — per-rank a2a bytes from the same capacity math the kernel
  uses (``dist.collectives.dispatch_capacity``); pure host arithmetic, no
  devices, exact (verified against the compiled HLO in
  ``tests/test_distributed.py::test_moe_dispatch_roofline_8dev``).
* **compiled** — ``lowered_moe_hlo`` builds the mesh, lowers
  ``models.moe.apply_moe`` and feeds the optimized HLO to ``analyze_hlo``
  (needs ``data * model`` local devices).

The point of the scenario: SP-aware expert parallelism (``ep_mode="sp"``)
cuts per-plane dispatch volume by |model| versus token replication —
``report(spec_sp)["a2a_bytes"] * |model| == report(spec_rep)["a2a_bytes"]``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict

SCENARIO_NAME = "moe_dispatch"


@dataclass(frozen=True)
class MoEDispatchSpec:
    """One cell of the MoE dispatch measurement space."""

    arch: str = "mixtral-8x7b"
    batch: int = 8
    seq: int = 32
    data: int = 4            # EP group size (mesh `data` axis)
    model: int = 2           # TP/SP plane count (mesh `model` axis)
    ep_mode: str = "replicated"
    capacity_factor: float = 8.0
    dtype_bytes: int = 4     # activation dtype (f32 smoke default)

    @property
    def name(self) -> str:
        return f"{SCENARIO_NAME}.{self.arch}.{self.ep_mode}"

    def config(self):
        """The reduced arch config with this spec's MoE knobs applied."""
        from ..configs import get_config, reduced

        return dataclasses.replace(
            reduced(get_config(self.arch)),
            moe_capacity_factor=self.capacity_factor,
            ep_mode=self.ep_mode,
        )


def analytic_a2a_bytes(spec: MoEDispatchSpec) -> Dict[str, float]:
    """Per-(data, model)-rank dispatch+combine all-to-all bytes, from the
    exact capacity math ``models.moe._moe_a2a`` uses.  Token rows move as
    ``dtype_bytes``-wide activations plus one int32 expert id per row on
    the dispatch leg."""
    from ..launch.mesh import moe_dispatch_planes
    from ..models.moe import virtual_experts
    from ..dist.collectives import dispatch_capacity

    cfg = spec.config()
    _, _, sub = virtual_experts(cfg.num_experts, cfg.d_ff)
    # mirror the kernel's divisibility fallback (models.moe._moe_a2a /
    # dist.sharding): an sp request degrades to replicated when the
    # sequence does not shard over `model`, and the batch stays
    # unsharded when it does not divide `data` — otherwise this analytic
    # model would report SP-reduced volume the kernel never achieves
    eff_mode = spec.ep_mode
    if eff_mode == "sp" and spec.seq % spec.model:
        eff_mode = "replicated"
    planes = moe_dispatch_planes(
        {"data": spec.data, "model": spec.model}, eff_mode)
    # tokens per rank inside the MoE region: batch over `data`; seq over
    # `model` when SP-aware, replicated otherwise
    seq_shard = spec.model if eff_mode == "sp" else 1
    b_shard = spec.data if spec.batch % spec.data == 0 else 1
    n_loc = (spec.batch // b_shard) * (spec.seq // seq_shard)
    sends = n_loc * cfg.num_experts_per_tok * sub
    cap = dispatch_capacity(sends, spec.data, spec.capacity_factor)
    d = cfg.d_model
    rows = spec.data * cap
    dispatch = rows * (d * spec.dtype_bytes + 4)  # activations + expert ids
    combine = rows * d * spec.dtype_bytes
    return {
        "cap": float(cap),
        "rows_per_rank": float(rows),
        # 1.0 when the SP reduction is actually in effect (a spec with
        # seq % model != 0 runs — and is modelled — as replicated)
        "sp_effective": float(eff_mode == "sp"),
        "a2a_bytes": float(dispatch + combine),   # per plane, per layer
        "dispatch_planes": float(planes),         # identical a2a copies
        # volume summed over the |model| physical planes (sp planes move
        # distinct 1/|model| shards; replicated planes move |model| copies)
        "a2a_bytes_all_planes": float((dispatch + combine) * spec.model),
    }


def lowered_moe_hlo(spec: MoEDispatchSpec) -> str:
    """Optimized HLO of one compiled MoE layer on a (data, model) mesh.

    Needs ``spec.data * spec.model`` local devices (tests use the
    ``XLA_FLAGS`` subprocess harness).
    """
    import jax
    import jax.numpy as jnp

    from ..dist.sharding import make_rules, use_rules
    from ..models import moe as MO
    from ..models.layers import split_leaves

    need = spec.data * spec.model
    if len(jax.devices()) < need:
        raise ValueError(
            f"moe_dispatch spec needs {need} devices "
            f"({spec.data}x{spec.model} mesh), have {len(jax.devices())}")
    cfg = spec.config()
    mesh = jax.make_mesh((spec.data, spec.model), ("data", "model"))
    rules = make_rules(mesh)
    params, _ = split_leaves(MO.init_moe(jax.random.PRNGKey(0), cfg))
    x = jnp.zeros((spec.batch, spec.seq, cfg.d_model), jnp.float32)
    with mesh, use_rules(rules):
        compiled = jax.jit(
            lambda p, xx: MO.apply_moe(p, xx, cfg, impl="a2a")
        ).lower(params, x).compile()
    return compiled.as_text()


def moe_dispatch_report(spec: MoEDispatchSpec,
                        compiled: bool = False) -> Dict[str, float]:
    """The scenario's measurements: analytic a2a bytes (always) plus the
    compiled-HLO collective bytes and interconnect roofline seconds when
    ``compiled`` (requires enough local devices)."""
    from ..launch.roofline import LINK_BW

    out = dict(analytic_a2a_bytes(spec))
    out["a2a_roofline_s"] = out["a2a_bytes"] / LINK_BW
    if compiled:
        from ..launch.roofline import analyze_hlo

        colls = analyze_hlo(lowered_moe_hlo(spec))["collectives"]
        out["hlo_a2a_bytes"] = float(colls.get("all-to-all", 0.0))
        out["hlo_allgather_bytes"] = float(colls.get("all-gather", 0.0))
        out["hlo_collective_bytes"] = float(colls.get("total", 0.0))
    return out
