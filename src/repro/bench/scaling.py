"""Weak-scaling study (paper §V-D/E): METG efficiency as ranks grow.

The one Task Bench headline the single-device families cannot reproduce
is the scaling study: *fixed work per rank*, rank count swept, and the
efficiency-vs-granularity contour compressing against the overhead floor
as ranks (and therefore communication) grow.  This module is that family:

``ScalingSpec``
    One weak-scaling cell series: a backend, a per-rank problem shape
    (``width_per_rank`` columns per rank — the graph at ``n`` ranks is
    ``n`` times wider), and the rank sweep (default ``{1, 2, 4, 8}``).

``run_scaling``
    The rank launcher.  JAX fixes its device count at process start, so
    each rank count is measured in a *relaunched subprocess* with
    ``JAX_NUM_CPU_DEVICES=n`` (jax >= 0.5) or
    ``XLA_FLAGS=--xla_force_host_platform_device_count=n`` (0.4.x) —
    the first in-repo multi-rank launcher; before this only CI set the
    variable.  The child (``python -m repro.bench.scaling``) runs the
    ordinary ``run_scenario`` sweep for its rank count and prints one
    JSON cell; the parent assembles the ``kind="metg_scaling"`` artifact:
    per-rank elapsed, weak-scaling efficiency ``T(1)/T(n)`` (ideal 1.0 —
    work per rank is constant), and the per-granularity contour.

Determinism: under the ``SyntheticTimer`` the child charges the
rank-count model (``SyntheticTimer.ranks``, a pure function of
``(graph, ranks, spec string)``), so the committed
``BENCH_metg_scaling.*`` baselines are machine-independent and the CI
``--baseline`` gate is noise-free; under the wall clock the child
really builds the backend's ``CommPlan`` over ``n`` devices.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import subprocess
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .scenario import ScenarioSpec, SweepControls
from .studies import _guarded_ratio
from .sweep import run_scenario
from .timers import SyntheticTimer, Timer, timer_config

RANKS: Tuple[int, ...] = (1, 2, 4, 8)

# the backends whose CommPlan paths are actually multi-rank (xla-scan /
# xla-static / host-dynamic execute on one device regardless of the
# runtime's device count, so a rank sweep over them measures nothing)
SCALING_BACKENDS: Tuple[str, ...] = (
    "shardmap-csp",
    "shardmap-csp[comm=onesided]",
    "shardmap-pipeline",
    "shardmap-pipeline[comm=onesided]",
    "auto",
)

WIDTH_PER_RANK = 4
# largest-first, spanning coarse (compute-bound, eff ~ 1) down to the
# overhead floor; the smoke resolution keeps the sub-64 points so even CI
# baselines have a 3-point contour
SCALING_SCHEDULE: Tuple[int, ...] = (4096, 256, 16, 1)
# a mid-size payload so the synthetic model's cross-rank comm term is
# visible against the compute term inside the rank sweep
SCALING_OUTPUT_BYTES = 4096
SCALING_SECONDS_PER_BYTE = 4e-9
SCALING_SECONDS_PER_RENDEZVOUS = 2e-6


@dataclass(frozen=True)
class ScalingSpec:
    """One weak-scaling series: fixed work per rank, swept rank count."""

    name: str
    backend: str = "shardmap-csp"
    pattern: str = "stencil"
    kernel: str = "compute"
    width_per_rank: int = WIDTH_PER_RANK
    height: int = 16
    output_bytes: int = SCALING_OUTPUT_BYTES
    ranks: Tuple[int, ...] = RANKS
    sweep: SweepControls = field(
        default_factory=lambda: SweepControls(schedule=SCALING_SCHEDULE,
                                              repeats=3))

    def __post_init__(self):
        if not self.name:
            raise ValueError("scaling scenario needs a name (artifact key)")
        if self.width_per_rank < 1:
            raise ValueError("width_per_rank must be >= 1")
        if not self.ranks or any(int(n) < 1 for n in self.ranks):
            raise ValueError("ranks must be a non-empty list of counts >= 1")
        if list(self.ranks) != sorted(set(int(n) for n in self.ranks)):
            raise ValueError(
                f"ranks must be strictly ascending, got {self.ranks}")
        if self.ranks[0] != 1:
            raise ValueError(
                "ranks must include 1 (the weak-scaling efficiency "
                "reference T(1) every other rank normalizes against)")

    @property
    def slug(self) -> str:
        return re.sub(r"[^A-Za-z0-9_.-]+", "-", self.name)

    def scenario_for(self, nranks: int, smoke: bool = False) -> ScenarioSpec:
        """The per-rank scenario: ``nranks`` times wider, same work/rank."""
        if nranks not in self.ranks:
            raise ValueError(f"rank count {nranks} not in {self.ranks}")
        return ScenarioSpec(
            name=f"{self.name}.r{nranks}",
            backend=self.backend,
            pattern=self.pattern,
            kernel=self.kernel,
            width=self.width_per_rank * nranks,
            height=self.height,
            output_bytes=self.output_bytes,
            sweep=self.sweep,
        ).with_smoke(smoke)


def scaling_timer(timer: Optional[Timer]) -> Optional[Timer]:
    """Specialize a ``SyntheticTimer`` with the scaling-study comm rates.

    The per-rank ``ranks`` knob is applied by the *child* (it knows its
    rank count); other timers pass through unchanged — the study is then
    a real multi-device measurement.
    """
    if not isinstance(timer, SyntheticTimer):
        return timer
    return dataclasses.replace(
        timer,
        seconds_per_byte=SCALING_SECONDS_PER_BYTE,
        seconds_per_rendezvous=SCALING_SECONDS_PER_RENDEZVOUS)


# ------------------------------------------------------ subprocess launch

def _jax_num_cpu_devices_supported() -> bool:
    """jax >= 0.5 reads ``JAX_NUM_CPU_DEVICES``; 0.4.x needs the XLA
    flag (and rejects setting both).  Resolved from package metadata so
    the parent never imports jax just to launch children."""
    try:
        from importlib.metadata import version

        major, minor = (int(x) for x in version("jax").split(".")[:2])
    except Exception:
        return True
    return (major, minor) >= (0, 5)


def rank_env(nranks: int, base: Optional[Dict[str, str]] = None,
             ) -> Dict[str, str]:
    """The child environment for an ``nranks``-device relaunch.

    Strips any inherited device-count forcing first (the CI multi-rank
    step exports ``JAX_NUM_CPU_DEVICES=8``; the child must see *its*
    rank count, not the parent's), keeps unrelated ``XLA_FLAGS``, and
    prepends this checkout's ``src`` so ``python -m repro.bench.scaling``
    resolves the same code the parent runs.
    """
    env = dict(os.environ if base is None else base)
    env.pop("JAX_NUM_CPU_DEVICES", None)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    if _jax_num_cpu_devices_supported():
        env["JAX_NUM_CPU_DEVICES"] = str(nranks)
    else:
        flags.append(f"--xla_force_host_platform_device_count={nranks}")
    if flags:
        env["XLA_FLAGS"] = " ".join(flags)
    else:
        env.pop("XLA_FLAGS", None)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    parts = [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                     if p and p != src]
    env["PYTHONPATH"] = os.pathsep.join(parts)
    return env


def _timer_payload(timer: Optional[Timer]) -> Optional[Dict]:
    """Serialize the parent's timer for the child relaunch."""
    if timer is None:
        return None
    if isinstance(timer, SyntheticTimer):
        return {"name": "synthetic", "config": timer_config(timer)}
    if timer.name == "wallclock":
        # the child rebuilds the default wall clock from the sweep
        # controls (exactly what a serial run_scenario does)
        return None
    raise ValueError(
        f"metg_scaling cannot relaunch under timer {timer.name!r}; "
        f"use the synthetic fake clock or the wall clock")


def _child_timer(payload: Optional[Dict], nranks: int) -> Optional[Timer]:
    if payload is None:
        return None
    cfg = dict(payload.get("config", {}))
    cfg["ranks"] = nranks
    return SyntheticTimer(**cfg)


def run_rank_cell(spec: ScalingSpec, nranks: int, smoke: bool,
                  timer_payload: Optional[Dict]) -> Dict:
    """Measure one (spec, rank count) cell — the child's whole job."""
    timer = _child_timer(timer_payload, nranks)
    sc = spec.scenario_for(nranks, smoke=smoke)
    result = run_scenario(sc, timer=timer)
    if timer is None:
        import jax

        devices = len(jax.devices())
    else:
        devices = nranks
    return {
        "ranks": nranks,
        "width": result.spec.width,
        "devices": devices,
        "timer": result.timer,
        "timer_config": dict(result.timer_config),
        "sweep": _sweep_doc(result.spec.sweep),
        "points": [
            {
                "iterations": p.iterations,
                "num_tasks": p.num_tasks,
                "wall_time_s": p.wall_time,
                "granularity_s": p.granularity,
                "efficiency": p.efficiency,
            }
            for p in sorted(result.points, key=lambda p: -p.iterations)
        ],
    }


def _sweep_doc(sweep: SweepControls) -> Dict:
    doc = dataclasses.asdict(sweep)
    doc["schedule"] = (list(sweep.schedule)
                       if sweep.schedule is not None else None)
    return doc


def _launch_cell(spec: ScalingSpec, nranks: int, smoke: bool,
                 timer_payload: Optional[Dict],
                 python: str) -> Dict:
    payload = json.dumps({
        "spec": {**dataclasses.asdict(spec),
                 "ranks": list(spec.ranks),
                 "sweep": _sweep_doc(spec.sweep)},
        "nranks": nranks,
        "smoke": smoke,
        "timer": timer_payload,
    })
    proc = subprocess.run(
        [python, "-m", "repro.bench.scaling"],
        input=payload, capture_output=True, text=True,
        env=rank_env(nranks))
    if proc.returncode != 0:
        tail = "\n".join(proc.stderr.strip().splitlines()[-8:])
        raise RuntimeError(
            f"metg_scaling child for {spec.name!r} at ranks={nranks} "
            f"exited {proc.returncode}:\n{tail}")
    try:
        return json.loads(proc.stdout)
    except json.JSONDecodeError as e:
        raise RuntimeError(
            f"metg_scaling child for {spec.name!r} at ranks={nranks} "
            f"printed unparseable output ({e}): {proc.stdout[:200]!r}")


def scaling_artifact(spec: ScalingSpec, cells: List[Dict],
                     smoke: bool) -> Dict:
    """Assemble the ``kind="metg_scaling"`` artifact from rank cells."""
    from .artifact import SCHEMA_VERSION, _canonical_backend

    cells = sorted(cells, key=lambda c: c["ranks"])
    base = {p["iterations"]: p["wall_time_s"]
            for p in cells[0]["points"]} if cells else {}
    out_cells = []
    for c in cells:
        points = []
        for p in c["points"]:
            ref = base.get(p["iterations"])
            points.append({**p, "weak_efficiency": _guarded_ratio(
                ref if ref is not None else float("nan"),
                p["wall_time_s"])})
        head = points[0] if points else {}
        out_cells.append({
            "ranks": c["ranks"],
            "width": c["width"],
            "devices": c["devices"],
            "elapsed_s": head.get("wall_time_s", 0.0),
            "granularity_s": head.get("granularity_s", 0.0),
            "weak_efficiency": head.get("weak_efficiency", 0.0),
            "points": points,
        })
    ref_sweep = cells[0]["sweep"] if cells else _sweep_doc(
        spec.scenario_for(spec.ranks[0], smoke=smoke).resolved().sweep)
    return {
        "schema": SCHEMA_VERSION,
        "kind": "metg_scaling",
        "scenario": {
            "name": spec.name,
            "backend": _canonical_backend(spec.backend),
            "pattern": spec.pattern,
            "kernel": spec.kernel,
            "width_per_rank": spec.width_per_rank,
            "height": spec.height,
            "output_bytes": spec.output_bytes,
            "ranks": [c["ranks"] for c in cells] or list(spec.ranks),
            "sweep": ref_sweep,
        },
        "timer": cells[0]["timer"] if cells else "wallclock",
        "timer_config": cells[0]["timer_config"] if cells else {},
        "cells": out_cells,
    }


@dataclass
class ScalingResult:
    """One assembled weak-scaling series, ready for the artifact writer."""

    spec: ScalingSpec
    doc: Dict

    @property
    def cells(self) -> List[Dict]:
        return self.doc["cells"]

    def cell(self, nranks: int) -> Dict:
        for c in self.cells:
            if c["ranks"] == nranks:
                return c
        raise KeyError(f"no cell for ranks={nranks}")


def run_scaling(spec: ScalingSpec, timer: Optional[Timer] = None,
                smoke: bool = False,
                python: str = sys.executable) -> ScalingResult:
    """Measure one weak-scaling series via per-rank subprocess relaunch."""
    from .artifact import validate_artifact

    payload = _timer_payload(scaling_timer(timer))
    cells = [_launch_cell(spec, n, smoke, payload, python)
             for n in spec.ranks]
    timers = {c["timer"] for c in cells}
    if len(timers) != 1:
        raise RuntimeError(
            f"metg_scaling children disagreed on the timer: {sorted(timers)}")
    doc = validate_artifact(scaling_artifact(spec, cells, smoke))
    return ScalingResult(spec=spec, doc=doc)


def write_scaling_json(result: ScalingResult, outdir: str) -> str:
    """Write ``BENCH_<scenario>.json`` (validated); returns the path."""
    from .artifact import write_artifact_doc

    return write_artifact_doc(result.doc, result.spec.slug, outdir)


def _child_main() -> None:
    req = json.load(sys.stdin)
    sp = dict(req["spec"])
    sp["ranks"] = tuple(sp["ranks"])
    sweep = dict(sp["sweep"])
    sweep["schedule"] = (tuple(sweep["schedule"])
                         if sweep["schedule"] is not None else None)
    sp["sweep"] = SweepControls(**sweep)
    spec = ScalingSpec(**sp)
    cell = run_rank_cell(spec, int(req["nranks"]), bool(req["smoke"]),
                         req["timer"])
    json.dump(cell, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    _child_main()
