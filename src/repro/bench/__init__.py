"""First-class METG measurement (paper §IV-V as a subsystem, not scripts).

- ``metg``     — the pure metric math: sweep points, efficiency curves,
                 METG crossover (re-exported by ``repro.core.metg``)
- ``scenario`` — declarative ``ScenarioSpec`` / ``SweepControls``
                 (pattern x kernel x payload x imbalance x ngraphs x backend)
- ``timers``   — the ``Timer`` protocol: wall clock, synthetic fake clock,
                 compiled dry-run roofline model
- ``sweep``    — ``run_scenario``: spec + timer -> ``ScenarioResult``
- ``artifact`` — schema-checked ``BENCH_<scenario>.json`` writer
- ``compare``  — artifact diffing: the bench-regression gate
                 (``benchmarks/run.py --baseline``)
- ``studies``  — the communication-hiding (``metg_payload``) and
                 load-imbalance (``metg_imbalance``) scenario families
                 and their derived metrics (overlap efficiency,
                 mitigation factor)
- ``moe``      — the ``moe_dispatch`` comm-volume scenario (SP-aware EP
                 vs token replication, dry-run roofline)
- ``serve``    — the ``serve_load`` scenario family: deterministic
                 open-loop serving traces against the continuous-batching
                 engine (real wall clock) or its discrete-event cost
                 model (synthetic), TTFT/TPOT/goodput percentiles
- ``scaling``  — the ``metg_scaling`` weak-scaling family (paper §V-D/E):
                 fixed work per rank, rank sweep via subprocess relaunch
                 with the JAX device count pinned
- ``suite``    — the declarative campaign orchestrator: a TOML file of
                 families x backends x repeats executed as concurrent
                 ``benchmarks.run`` subprocesses (``benchmarks/suite.py``)

``benchmarks/*.py`` are thin wrappers over this package; multi-graph
scenarios (``ngraphs >= 2``) execute concurrently through
``Backend.run_many``.
"""
# .metg must be imported first: repro.core.metg re-exports it, and the
# other submodules here import repro.core, so a partially-initialized
# package must already expose the pure math.
from .metg import (METGResult, SweepPoint, compute_metg, efficiency_curve,
                   geometric_iterations, observed_peak, run_sweep,
                   sweep_point, time_run)
from .scenario import ScenarioSpec, SweepControls
from .timers import DryRunTimer, SyntheticTimer, Timer, WallClockTimer
from .sweep import ScenarioResult, run_scenario
from .artifact import (SCHEMA_VERSION, bench_artifact, read_bench_json,
                       validate_artifact, write_bench_json)
from .compare import (ComparisonResult, PointDelta, bench_json_names,
                      compare_artifacts, compare_dirs, format_report,
                      scenario_family)
from .studies import (StudyPoint, elapsed_s, imbalance_spec,
                      imbalance_study_specs, mitigation_curve,
                      mitigation_factor, observed_rate, overlap_efficiency,
                      payload_curve, payload_spec, payload_study_specs,
                      study_timer)
from .moe import (MoEDispatchSpec, analytic_a2a_bytes, lowered_moe_hlo,
                  moe_dispatch_report)
from .tuner import (TuningKey, TuningTable, auto_resolve, build_tuning_table,
                    diff_tuning_tables, enumerate_mode_space, graphs_cutout,
                    granularity_bucket, load_tuning_table, payload_bucket,
                    read_tuning_json, spec_cutout, validate_tuning_table,
                    write_tuning_json)
from .serve import (ServeCostParams, ServeLoadResult, ServeLoadSpec,
                    TracedRequest, run_engine_load, run_serve_load,
                    serve_artifact, simulate_serve_load, synth_trace,
                    write_serve_json)
from .scaling import (RANKS, SCALING_BACKENDS, ScalingResult, ScalingSpec,
                      rank_env, run_scaling, scaling_artifact,
                      write_scaling_json)
from .suite import (Suite, SuiteCell, SuiteResult, load_suite, parse_suite,
                    run_suite, validate_suite)

__all__ = [
    "METGResult",
    "SweepPoint",
    "compute_metg",
    "efficiency_curve",
    "geometric_iterations",
    "observed_peak",
    "run_sweep",
    "sweep_point",
    "time_run",
    "ScenarioSpec",
    "SweepControls",
    "Timer",
    "WallClockTimer",
    "SyntheticTimer",
    "DryRunTimer",
    "ScenarioResult",
    "run_scenario",
    "SCHEMA_VERSION",
    "bench_artifact",
    "read_bench_json",
    "validate_artifact",
    "write_bench_json",
    "ComparisonResult",
    "PointDelta",
    "compare_artifacts",
    "compare_dirs",
    "format_report",
    "StudyPoint",
    "elapsed_s",
    "imbalance_spec",
    "imbalance_study_specs",
    "mitigation_curve",
    "mitigation_factor",
    "observed_rate",
    "overlap_efficiency",
    "payload_curve",
    "payload_spec",
    "payload_study_specs",
    "study_timer",
    "MoEDispatchSpec",
    "analytic_a2a_bytes",
    "lowered_moe_hlo",
    "moe_dispatch_report",
    "TuningKey",
    "TuningTable",
    "auto_resolve",
    "build_tuning_table",
    "diff_tuning_tables",
    "enumerate_mode_space",
    "granularity_bucket",
    "graphs_cutout",
    "load_tuning_table",
    "payload_bucket",
    "read_tuning_json",
    "spec_cutout",
    "validate_tuning_table",
    "write_tuning_json",
    "ServeCostParams",
    "ServeLoadResult",
    "ServeLoadSpec",
    "TracedRequest",
    "run_engine_load",
    "run_serve_load",
    "serve_artifact",
    "simulate_serve_load",
    "synth_trace",
    "write_serve_json",
    "RANKS",
    "SCALING_BACKENDS",
    "ScalingResult",
    "ScalingSpec",
    "rank_env",
    "run_scaling",
    "scaling_artifact",
    "write_scaling_json",
    "Suite",
    "SuiteCell",
    "SuiteResult",
    "load_suite",
    "parse_suite",
    "run_suite",
    "validate_suite",
]
