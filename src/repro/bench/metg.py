"""Minimum Effective Task Granularity — the paper's §IV metric.

METG(e) for a workload is the smallest *average task granularity* (wall time
x cores / #tasks) at which the workload still achieves at least fraction
``e`` of its best observed rate (FLOP/s for compute kernels, B/s for memory
kernels).

The harness sweeps task duration (kernel iterations) from large to small at
fixed graph shape and hardware (paper: "measured in place"), replots the
points on (granularity, efficiency) axes and log-interpolates the 50 %
crossing, exactly as paper Figures 2-3 construct it.

This module is pure math over ``SweepPoint`` records: it imports nothing
from the rest of ``repro`` so that ``repro.core.metg`` (the compatibility
re-export) and ``repro.bench`` proper can both depend on it freely.
Measurement — how wall times are produced — lives in ``repro.bench.timers``
and ``repro.bench.sweep``.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence


@dataclass
class SweepPoint:
    iterations: int
    wall_time: float  # seconds, best of repeats
    num_tasks: int
    useful_work: float  # FLOPs or bytes
    granularity: float = 0.0  # seconds per task (x cores)
    rate: float = 0.0  # work / second
    efficiency: float = 0.0  # rate / peak_rate


@dataclass
class METGResult:
    metg: Optional[float]  # seconds; None if curve never crosses
    threshold: float
    peak_rate: float
    points: List[SweepPoint] = field(default_factory=list)

    def csv_rows(self) -> List[str]:
        rows = []
        for p in sorted(self.points, key=lambda p: -p.iterations):
            rows.append(
                f"{p.iterations},{p.wall_time:.6e},{p.granularity:.6e},"
                f"{p.rate:.6e},{p.efficiency:.4f}"
            )
        return rows


def time_run(fn: Callable[[], None], repeats: int = 3) -> float:
    """Best-of-N wall time of fn() (fn must block until complete)."""
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep_point(graphs: Sequence, iterations: int, wall: float,
                cores: int = 1) -> SweepPoint:
    """Build the sweep point for one measured execution of ``graphs``.

    The single definition of granularity (wall x cores / #tasks) and
    useful work, shared by the legacy callable API (``run_sweep``) and the
    scenario harness (``repro.bench.sweep.run_scenario``).
    """
    num_tasks = sum(g.num_tasks for g in graphs)
    work = sum(g.total_useful_work() for g in graphs)
    return SweepPoint(
        iterations=iterations,
        wall_time=wall,
        num_tasks=num_tasks,
        useful_work=work,
        granularity=wall * cores / num_tasks,
    )


def run_sweep(
    make_runner: Callable[[int], Callable[[], None]],
    graphs_at: Callable[[int], Sequence],
    iterations_list: Sequence[int],
    cores: int = 1,
    repeats: int = 3,
) -> List[SweepPoint]:
    """Measure wall time for each task duration in the sweep.

    ``make_runner(iters)`` returns a zero-arg callable that executes the
    workload to completion (compile/warmup must happen before timing: the
    harness invokes the runner once untimed).  ``graphs_at(iters)`` returns
    the ``TaskGraph`` list the runner executes (consulted for task counts
    and useful work only).
    """
    points = []
    for iters in iterations_list:
        graphs = list(graphs_at(iters))
        runner = make_runner(iters)
        runner()  # warmup / compile
        wall = time_run(runner, repeats=repeats)
        points.append(sweep_point(graphs, iters, wall, cores=cores))
    return points


def observed_peak(points: Sequence[SweepPoint]) -> float:
    """The 100 %-efficiency baseline: best rate in the sweep (paper §V-A).

    The single definition of self-normalization, shared by
    ``efficiency_curve`` and ``compute_metg``.  Points must have ``rate``
    filled in.
    """
    return max((p.rate for p in points), default=0.0)


def efficiency_curve(
    points: Sequence[SweepPoint],
    peak_rate: Optional[float] = None,
) -> List[SweepPoint]:
    """Replot sweep points on (granularity, efficiency) axes.

    Returns fresh ``SweepPoint`` copies with ``rate`` and ``efficiency``
    filled in; ``peak_rate`` defaults to ``observed_peak`` of the sweep
    itself (the empirically-achieved peak is the 100 % baseline).
    """
    pts = [SweepPoint(**vars(p)) for p in points]
    for p in pts:
        p.rate = p.useful_work / p.wall_time if p.wall_time > 0 else 0.0
    if peak_rate is None:
        peak_rate = observed_peak(pts)
    for p in pts:
        p.efficiency = p.rate / peak_rate if peak_rate > 0 else 0.0
    return pts


def compute_metg(
    points: Sequence[SweepPoint],
    threshold: float = 0.5,
    peak_rate: Optional[float] = None,
) -> METGResult:
    """Build the efficiency curve and find the threshold crossing."""
    pts = efficiency_curve(points, peak_rate=peak_rate)
    if peak_rate is None:
        peak_rate = observed_peak(pts)
    if peak_rate <= 0:
        return METGResult(metg=None, threshold=threshold, peak_rate=0.0, points=pts)

    # The smallest granularity still >= threshold; if the next smaller
    # point dips below, log-interpolate the crossing (robust to small
    # non-monotonicity from timing noise).
    ordered = sorted(pts, key=lambda p: -p.granularity)
    above = [p for p in ordered if p.efficiency >= threshold]
    if not above:
        return METGResult(metg=None, threshold=threshold,
                          peak_rate=peak_rate, points=pts)
    prev = above[-1]  # smallest granularity at/above threshold
    metg: Optional[float] = prev.granularity
    below = [p for p in ordered
             if p.granularity < prev.granularity and p.efficiency < threshold]
    if below:
        p = below[0]  # largest-granularity point below threshold
        if prev.efficiency > p.efficiency and p.granularity > 0:
            lo_g, hi_g = math.log(p.granularity), math.log(prev.granularity)
            lo_e, hi_e = p.efficiency, prev.efficiency
            frac = (threshold - lo_e) / (hi_e - lo_e)
            metg = math.exp(lo_g + frac * (hi_g - lo_g))
    return METGResult(metg=metg, threshold=threshold, peak_rate=peak_rate, points=pts)


def geometric_iterations(hi: int, lo: int = 1, factor: float = 2.0) -> List[int]:
    """Sweep schedule: hi, hi/f, ... down to lo (deduplicated)."""
    if not 1 <= lo <= hi:
        raise ValueError(f"need 1 <= lo <= hi, got lo={lo}, hi={hi}")
    out, x = [], float(hi)
    while x >= lo:
        v = max(lo, int(round(x)))
        if not out or v != out[-1]:
            out.append(v)
        x /= factor
    if out[-1] != lo:
        out.append(lo)
    return out
