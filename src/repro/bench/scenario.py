"""Declarative benchmark scenarios: what to measure, as plain data.

A ``ScenarioSpec`` names one cell of the paper's measurement space —
dependence pattern x kernel x payload x imbalance x number of concurrent
graphs x backend — plus the sweep controls (``SweepControls``) that decide
how task granularity is swept.  Specs compile to runnable graph lists via
``core.make_graph``/``replicate`` and are executed by
``repro.bench.sweep.run_scenario`` under a pluggable ``Timer``.

Smoke mode is a *spec parameter* (``SweepControls.smoke``), not ambient
state: ``resolved()`` returns the spec a smoke run actually measures
(tiny schedule, one repeat, shallow graphs), so CI and full sweeps share
one code path and the artifact records which controls were in force.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from ..core.graph import TaskGraph, make_graph, replicate
from .metg import geometric_iterations

# smoke-mode ceilings (previously a module-level SMOKE global mutated by
# benchmarks/run.py; now declarative so sweeps are reproducible from the spec)
SMOKE_ITERATIONS_HI = 64
SMOKE_N_POINTS = 3
SMOKE_HEIGHT = 8


@dataclass(frozen=True)
class SweepControls:
    """How task granularity is swept and timed for one scenario."""

    iterations_hi: int = 4096
    iterations_lo: int = 1
    n_points: int = 7
    repeats: int = 3          # timed repetitions per point (wall-clock timer)
    warmup: int = 1           # untimed runs before timing (compile/caches)
    percentile: float = 0.0   # 0 => best-of-repeats; else percentile of samples
    threshold: float = 0.5    # METG efficiency threshold (paper: 50 %)
    schedule: Optional[Tuple[int, ...]] = None  # explicit iteration list
    smoke: bool = False       # CI mode: shrink the sweep to a token size

    def __post_init__(self):
        if self.iterations_lo < 1:
            raise ValueError("iterations_lo must be >= 1")
        if self.iterations_hi < self.iterations_lo:
            raise ValueError(
                f"iterations_hi ({self.iterations_hi}) must be >= "
                f"iterations_lo ({self.iterations_lo})")
        if self.n_points < 1:
            raise ValueError("n_points must be >= 1")
        if self.schedule is not None and (
                not self.schedule or any(s < 1 for s in self.schedule)):
            raise ValueError("schedule must be a non-empty list of "
                             "iteration counts >= 1")

    def resolved(self) -> "SweepControls":
        """The controls actually used (smoke ceilings applied)."""
        if not self.smoke:
            return self
        schedule = self.schedule
        if schedule is not None:
            capped: List[int] = []
            for s in schedule:
                v = min(int(s), SMOKE_ITERATIONS_HI)
                if v not in capped:
                    capped.append(v)
            schedule = tuple(capped[:SMOKE_N_POINTS])
        return dataclasses.replace(
            self,
            iterations_hi=min(self.iterations_hi, SMOKE_ITERATIONS_HI),
            # cap the floor too: replace() re-validates hi >= lo
            iterations_lo=min(self.iterations_lo, SMOKE_ITERATIONS_HI),
            n_points=min(self.n_points, SMOKE_N_POINTS),
            repeats=1,
            warmup=min(self.warmup, 1),
            schedule=schedule,
        )

    def iteration_schedule(self) -> List[int]:
        """Task durations to sweep, largest first."""
        c = self.resolved()
        if c.schedule is not None:
            return list(c.schedule)
        factor = max(2.0, c.iterations_hi ** (1.0 / max(c.n_points - 1, 1)))
        return geometric_iterations(c.iterations_hi, c.iterations_lo,
                                    factor)[: c.n_points]


@dataclass(frozen=True)
class ScenarioSpec:
    """One measurement scenario: graph family x backend x sweep controls."""

    name: str
    backend: str = "xla-scan"
    pattern: str = "stencil"
    kernel: str = "compute"
    width: int = 8
    height: int = 32
    output_bytes: int = 16
    imbalance: float = 0.0
    ngraphs: int = 1          # concurrent task graphs (paper Fig 9d)
    cores: int = 1            # granularity = wall * cores / tasks
    graph_kw: Tuple[Tuple[str, object], ...] = ()  # radix/seed/span_bytes/...
    sweep: SweepControls = field(default_factory=SweepControls)

    def __post_init__(self):
        if not self.name:
            raise ValueError("scenario needs a name (artifact key)")
        if self.ngraphs < 1:
            raise ValueError("ngraphs must be >= 1")

    @property
    def slug(self) -> str:
        """Filesystem-safe scenario key: BENCH_<slug>.json."""
        return re.sub(r"[^A-Za-z0-9_.-]+", "-", self.name)

    def resolved(self) -> "ScenarioSpec":
        """The spec a run actually measures (smoke ceilings applied)."""
        if not self.sweep.smoke:
            return self
        return dataclasses.replace(
            self,
            height=min(self.height, SMOKE_HEIGHT),
            sweep=self.sweep.resolved(),
        )

    # -- compilation to runnable graphs -------------------------------------
    def graph(self, iterations: int) -> TaskGraph:
        return make_graph(
            width=self.width,
            height=self.height,
            pattern=self.pattern,
            kernel=self.kernel,
            iterations=iterations,
            output_bytes=self.output_bytes,
            imbalance=self.imbalance,
            **dict(self.graph_kw),
        )

    def graphs(self, iterations: int) -> List[TaskGraph]:
        """The concurrent graph list ``run_many`` executes."""
        return replicate(self.graph(iterations), self.ngraphs)

    def make_backend(self):
        from ..backends import get_backend  # deferred: jax-heavy

        return get_backend(self.backend)

    def with_smoke(self, smoke: bool = True) -> "ScenarioSpec":
        return dataclasses.replace(
            self, sweep=dataclasses.replace(self.sweep, smoke=smoke))
