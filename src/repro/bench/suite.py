"""Declarative benchmark campaigns: a TOML suite of bench families.

The scenario families (``benchmarks/bench_*.py``) have so far been run
one module at a time; a paper-style campaign is the cross product —
families x backends x repeats — plus bookkeeping (artifact collection,
table aggregation, the baseline gate).  This module makes the campaign a
*document* instead of a shell history (TaPS-style):

.. code-block:: toml

    name = "paper"
    parallel = 4          # concurrent cells (subprocesses)
    timer = "synthetic"   # suite default; cells may override

    [[tasks]]
    family = "bench_metg_patterns"
    backends = ["xla-scan", "shardmap-csp"]   # optional --backends filter
    rollouts = 2                              # repeat runs; byte-compared

Execution model: every cell is one ``python -m benchmarks.run --only
<family>`` subprocess — exactly the serial CLI, so a suite run writes
the *same* ``BENCH_*.json`` artifacts a serial run would (bit-identical
on the synthetic timer; asserted for rollouts).  ``parallel = N`` runs
up to N cells concurrently; artifact filenames are disjoint because one
family's scenarios share its name prefix and duplicate families are
rejected at validation time.  A failed cell fails the suite, but every
other cell still runs to completion (the failure names the cell).

``rollouts = k`` re-runs a cell ``k - 1`` extra times into
``<out>/rollouts/<family>.rN/`` and byte-compares each rollout's
artifacts against the primary run's — on the deterministic synthetic
timer any difference is a real nondeterminism bug (unseeded RNG, dict
ordering, clock leakage), so a mismatch fails the suite.  Wall-clock
rollouts are kept for inspection but not compared (timing noise is not
a bug).

``benchmarks/suite.py`` is the CLI wrapper: TOML in, artifacts +
baseline gate + EXPERIMENTS.md tables out.
"""
from __future__ import annotations

import filecmp
import os
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

try:  # py >= 3.11
    import tomllib
except ImportError:  # the container's 3.10: same API, vendored package
    import tomli as tomllib

TIMERS = ("synthetic", "wallclock")


@dataclass(frozen=True)
class SuiteCell:
    """One campaign cell: a bench family plus its run knobs."""

    family: str
    backends: Optional[Tuple[str, ...]] = None  # None -> module defaults
    rollouts: int = 1
    timer: Optional[str] = None  # None -> suite default

    def __post_init__(self):
        if not self.family:
            raise ValueError("suite cell needs a family (bench module name)")
        if self.rollouts < 1:
            raise ValueError(
                f"cell {self.family!r}: rollouts must be >= 1, "
                f"got {self.rollouts}")
        if self.timer is not None and self.timer not in TIMERS:
            raise ValueError(
                f"cell {self.family!r}: unknown timer {self.timer!r}; "
                f"known: {TIMERS}")
        if self.backends is not None and not self.backends:
            raise ValueError(
                f"cell {self.family!r}: backends = [] would filter every "
                f"backend out; omit the key to run the module's defaults")


@dataclass(frozen=True)
class Suite:
    """A parsed campaign: named, bounded concurrency, ordered cells."""

    name: str
    cells: Tuple[SuiteCell, ...]
    parallel: int = 1
    timer: str = "synthetic"

    def __post_init__(self):
        if not self.name:
            raise ValueError("suite needs a name")
        if self.parallel < 1:
            raise ValueError(f"parallel must be >= 1, got {self.parallel}")
        if self.timer not in TIMERS:
            raise ValueError(
                f"unknown suite timer {self.timer!r}; known: {TIMERS}")
        if not self.cells:
            raise ValueError("suite has no [[tasks]] cells")

    def cell_timer(self, cell: SuiteCell) -> str:
        return cell.timer or self.timer


def parse_suite(text: str, source: str = "<suite>") -> Suite:
    """Parse TOML into a ``Suite``; structural errors name ``source``."""
    try:
        doc = tomllib.loads(text)
    except tomllib.TOMLDecodeError as e:
        raise ValueError(f"{source}: not valid TOML: {e}")
    known_top = {"name", "parallel", "timer", "tasks"}
    unknown = sorted(set(doc) - known_top)
    if unknown:
        raise ValueError(
            f"{source}: unknown top-level key(s) {unknown}; "
            f"known: {sorted(known_top)}")
    tasks = doc.get("tasks")
    if not isinstance(tasks, list) or not tasks:
        raise ValueError(f"{source}: needs at least one [[tasks]] cell")
    known_cell = {"family", "backends", "rollouts", "timer"}
    cells = []
    for i, t in enumerate(tasks):
        if not isinstance(t, dict):
            raise ValueError(f"{source}: [[tasks]] entry #{i + 1} is not "
                             f"a table")
        unknown = sorted(set(t) - known_cell)
        if unknown:
            raise ValueError(
                f"{source}: [[tasks]] entry #{i + 1} "
                f"({t.get('family', '?')!r}): unknown key(s) {unknown}; "
                f"known: {sorted(known_cell)}")
        backends = t.get("backends")
        if backends is not None:
            if (not isinstance(backends, list)
                    or any(not isinstance(b, str) for b in backends)):
                raise ValueError(
                    f"{source}: [[tasks]] entry #{i + 1} "
                    f"({t.get('family', '?')!r}): backends must be a list "
                    f"of spec strings")
            backends = tuple(backends)
        try:
            cells.append(SuiteCell(
                family=str(t.get("family", "")),
                backends=backends,
                rollouts=int(t.get("rollouts", 1)),
                timer=t.get("timer")))
        except ValueError as e:
            raise ValueError(f"{source}: [[tasks]] entry #{i + 1}: {e}")
    try:
        return Suite(name=str(doc.get("name", "")),
                     cells=tuple(cells),
                     parallel=int(doc.get("parallel", 1)),
                     timer=doc.get("timer", "synthetic"))
    except ValueError as e:
        raise ValueError(f"{source}: {e}")


def load_suite(path: str) -> Suite:
    with open(path, "rb") as f:
        text = f.read().decode("utf-8")
    return parse_suite(text, source=path)


def validate_suite(suite: Suite, known_families: Sequence[str],
                   known_backends: Optional[Sequence[str]] = None) -> None:
    """Reject cells naming unknown families/backends (and duplicates).

    Runs before any subprocess is spawned: a typo'd family must exit
    nonzero *naming the entry*, never launch a partial campaign.
    Backend specs are checked by parsing (``auto[...]`` and option
    brackets are legal spec syntax, not registry keys); duplicate
    families are rejected because two cells of one family would race on
    the same ``BENCH_*.json`` filenames.
    """
    problems = []
    seen: Dict[str, int] = {}
    for i, cell in enumerate(suite.cells, 1):
        if cell.family not in known_families:
            problems.append(
                f"[[tasks]] entry #{i}: unknown family {cell.family!r}; "
                f"known: {', '.join(known_families)}")
            continue
        if cell.family in seen:
            problems.append(
                f"[[tasks]] entry #{i}: duplicate family {cell.family!r} "
                f"(already cell #{seen[cell.family]}; two cells of one "
                f"family would overwrite each other's artifacts)")
        seen.setdefault(cell.family, i)
        for b in cell.backends or ():
            try:
                from ..backends.base import parse_backend_spec

                base, _ = parse_backend_spec(b)
            except ValueError as e:
                problems.append(
                    f"[[tasks]] entry #{i} ({cell.family!r}): malformed "
                    f"backend spec {b!r}: {e}")
                continue
            if (known_backends is not None and base != "auto"
                    and base not in known_backends):
                problems.append(
                    f"[[tasks]] entry #{i} ({cell.family!r}): unknown "
                    f"backend {b!r}; known: "
                    f"{', '.join(known_backends)} (+ auto)")
    if problems:
        raise ValueError(
            f"suite {suite.name!r} failed validation:\n  "
            + "\n  ".join(problems))


@dataclass
class CellRun:
    """One executed cell (or rollout): its command and outcome."""

    cell: SuiteCell
    out_dir: str
    rollout: int  # 0 = primary run
    returncode: int
    stdout: str
    stderr: str

    @property
    def ok(self) -> bool:
        return self.returncode == 0

    @property
    def label(self) -> str:
        base = self.cell.family
        return base if self.rollout == 0 else f"{base}.r{self.rollout}"


@dataclass
class SuiteResult:
    """A completed campaign: every cell run + derived failure lists."""

    suite: Suite
    out_dir: str
    runs: List[CellRun] = field(default_factory=list)
    # (label, detail) pairs: cells that exited nonzero / rollouts whose
    # artifacts differed from the primary run's
    failures: List[Tuple[str, str]] = field(default_factory=list)
    mismatches: List[Tuple[str, str]] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures and not self.mismatches

    def summary(self) -> str:
        lines = []
        for r in self.runs:
            lines.append(f"{r.label}: {'ok' if r.ok else f'EXIT {r.returncode}'}")
        for label, detail in self.mismatches:
            lines.append(f"{label}: ROLLOUT MISMATCH {detail}")
        lines.append(
            f"suite {self.suite.name!r}: {len(self.runs)} cell run(s), "
            + ("all ok" if self.ok
               else f"{len(self.failures)} failure(s), "
                    f"{len(self.mismatches)} rollout mismatch(es)"))
        return "\n".join(lines)


def _repo_root() -> str:
    # src/repro/bench/suite.py -> repo checkout root
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def cell_command(suite: Suite, cell: SuiteCell, out_dir: str,
                 smoke: bool, python: str = sys.executable) -> List[str]:
    """The exact serial CLI a cell runs — one family of ``benchmarks.run``."""
    cmd = [python, "-m", "benchmarks.run",
           "--only", cell.family,
           "--artifacts", out_dir,
           "--timer", suite.cell_timer(cell)]
    if smoke:
        cmd.append("--smoke")
    if cell.backends:
        cmd += ["--backends", ",".join(cell.backends)]
    return cmd


def _run_cell(suite: Suite, cell: SuiteCell, out_dir: str, rollout: int,
              smoke: bool, python: str, cwd: str,
              env: Dict[str, str]) -> CellRun:
    os.makedirs(out_dir, exist_ok=True)
    proc = subprocess.run(
        cell_command(suite, cell, out_dir, smoke, python),
        capture_output=True, text=True, cwd=cwd, env=env)
    return CellRun(cell=cell, out_dir=out_dir, rollout=rollout,
                   returncode=proc.returncode,
                   stdout=proc.stdout, stderr=proc.stderr)


def rollout_dir(out_dir: str, cell: SuiteCell, rollout: int) -> str:
    return os.path.join(out_dir, "rollouts", f"{cell.family}.r{rollout}")


def _compare_rollout(primary_dir: str, rollout_run: CellRun,
                     ) -> List[Tuple[str, str]]:
    """Byte-compare a rollout's artifacts against the primary run's."""
    from .compare import bench_json_names

    mismatches = []
    names = bench_json_names(rollout_run.out_dir)
    if not names:
        mismatches.append((rollout_run.label,
                           "rollout wrote no BENCH_*.json artifacts"))
    for fname in names:
        primary = os.path.join(primary_dir, fname)
        current = os.path.join(rollout_run.out_dir, fname)
        if not os.path.exists(primary):
            mismatches.append(
                (rollout_run.label,
                 f"{fname} exists only in the rollout"))
        elif not filecmp.cmp(primary, current, shallow=False):
            mismatches.append(
                (rollout_run.label,
                 f"{fname} differs byte-wise from the primary run "
                 f"(nondeterminism on the deterministic timer)"))
    return mismatches


def run_suite(suite: Suite, out_dir: str, smoke: bool = False,
              python: str = sys.executable,
              cwd: Optional[str] = None,
              parallel: Optional[int] = None) -> SuiteResult:
    """Execute every cell (and its rollouts) and collect the outcome.

    Cells run as ``benchmarks.run`` subprocesses, at most
    ``parallel`` (default: the suite's ``parallel``) at a time; a
    nonzero cell never cancels the others.  Rollout byte-comparison
    applies only to synthetic-timer cells.
    """
    cwd = cwd or _repo_root()
    env = dict(os.environ)
    src = os.path.join(cwd, "src")
    parts = [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                     if p and p != src]
    env["PYTHONPATH"] = os.pathsep.join(parts)
    out_dir = os.path.abspath(out_dir)

    jobs = []  # (cell, rollout, dir)
    for cell in suite.cells:
        jobs.append((cell, 0, out_dir))
        for r in range(1, cell.rollouts):
            jobs.append((cell, r, rollout_dir(out_dir, cell, r)))

    nworkers = parallel if parallel is not None else suite.parallel
    result = SuiteResult(suite=suite, out_dir=out_dir)
    with ThreadPoolExecutor(max_workers=max(1, nworkers)) as pool:
        futures = [pool.submit(_run_cell, suite, cell, d, r, smoke,
                               python, cwd, env)
                   for cell, r, d in jobs]
        runs = [f.result() for f in futures]

    order = {(c.family, r): i for i, (c, r, _) in enumerate(jobs)}
    runs.sort(key=lambda cr: order[(cr.cell.family, cr.rollout)])
    result.runs = runs
    for cr in runs:
        if not cr.ok:
            tail = "\n".join((cr.stderr.strip() or cr.stdout.strip())
                             .splitlines()[-5:])
            result.failures.append((cr.label, tail))
    ok_primary = {cr.cell.family for cr in runs
                  if cr.rollout == 0 and cr.ok}
    for cr in runs:
        if (cr.rollout > 0 and cr.ok
                and cr.cell.family in ok_primary
                and suite.cell_timer(cr.cell) == "synthetic"):
            result.mismatches.extend(_compare_rollout(out_dir, cr))
    return result
