"""Pluggable timers: how a scenario's wall time is produced.

One ``Timer`` protocol, three implementations spanning the measurement
spectrum:

``WallClockTimer``
    Real measurement: prepares the backend's concurrent program
    (``Backend.prepare_many``) and times repeated blocking executions,
    with warmup and percentile controls (0 = paper-style best-of-N).

``SyntheticTimer``
    The deterministic fake clock: the paper's overhead model
    ``wall = sum_tasks (overhead + iterations * seconds_per_iteration)``
    evaluated in closed form.  No JAX, no timing noise — CI asserts exact
    METG crossovers against the analytic curve.

``DryRunTimer``
    Compiled dry-run cost model: lowers the backend's program, walks the
    optimized HLO with ``launch.roofline.analyze_hlo``, and reports the
    binding roofline term (compute / HBM / interconnect) as the wall
    time.  Deterministic given a compiler version; no execution.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Protocol, Sequence, Tuple, runtime_checkable

from ..core.graph import TaskGraph


@runtime_checkable
class Timer(Protocol):
    """Produces the wall time of one complete multi-graph execution."""

    name: str

    def measure(self, backend_name: str, graphs: Sequence[TaskGraph]) -> float:
        """Seconds for one blocking execution of ``graphs`` (concurrently)."""
        ...


def timer_config(timer: Timer) -> Dict[str, object]:
    """The timer's public parameters, for the artifact record.

    A custom (non-dataclass) Timer may expose its own ``config()`` dict;
    otherwise its settings are unrecorded (empty dict).
    """
    if hasattr(timer, "config") and callable(timer.config):
        return dict(timer.config())
    if dataclasses.is_dataclass(timer):
        return {f.name: getattr(timer, f.name)
                for f in dataclasses.fields(timer)
                if f.repr and f.name != "name"}
    return {}


def cached_backend(cache: Dict[str, object], backend_name: str):
    """Per-timer backend cache (avoids re-building meshes per sweep point)."""
    if backend_name not in cache:
        from ..backends import get_backend

        cache[backend_name] = get_backend(backend_name)
    return cache[backend_name]


def backend_dispatch_model(backend_name: str) -> str:
    """Which dispatch-cost model a backend's execution implies.

    Resolved *leniently* from the registered class's ``dispatch_model``
    attribute — by name only, never by instantiation, and unknown or
    malformed names fall back to ``"per-task"`` — so the default
    synthetic configuration stays backend-free (the model tests feed it
    nonexistent backend names on purpose).
    """
    try:
        from ..backends.base import _BACKENDS, parse_backend_spec

        base, _ = parse_backend_spec(backend_name)
        cls = _BACKENDS.get(base)
    except Exception:
        return "per-task"
    if cls is None:
        return "per-task"
    return getattr(cls, "dispatch_model", "per-task")


def backend_comm_hints(backend_name: str) -> Tuple[bool, bool]:
    """``(onesided, overlap)`` for a backend spec, resolved by name only.

    The multi-rank synthetic model (``SyntheticTimer.ranks > 1``) needs
    the spec's communication mode without instantiating the backend —
    the rank sweep runs in relaunched subprocesses and the charged model
    must be a pure function of the spec string, never of the runtime's
    device count.  Malformed specs resolve to blocking two-sided (the
    conservative model), mirroring ``backend_dispatch_model``'s lenient
    fallback.
    """
    try:
        from ..backends.base import parse_backend_spec

        _, kw = parse_backend_spec(backend_name)
    except Exception:
        return False, False
    return kw.get("comm") == "onesided", kw.get("comm_overlap") is True


def pick_sample(samples: Sequence[float], percentile: float) -> float:
    """Select the reported time: <=0 -> min (best-of-N), else percentile."""
    if not samples:
        raise ValueError("no timing samples")
    if percentile <= 0:
        return min(samples)
    ordered = sorted(samples)
    idx = max(0, min(len(ordered) - 1,
                     math.ceil(percentile / 100.0 * len(ordered)) - 1))
    return ordered[idx]


@dataclass
class WallClockTimer:
    """Times real backend executions (prepare once, run repeatedly)."""

    warmup: int = 1
    repeats: int = 3
    percentile: float = 0.0  # 0 => best-of-repeats
    name: str = field(default="wallclock", init=False)
    _backends: Dict[str, object] = field(default_factory=dict, repr=False)

    def measure(self, backend_name: str, graphs: Sequence[TaskGraph]) -> float:
        runner = cached_backend(self._backends, backend_name).prepare_many(graphs)
        for _ in range(max(self.warmup, 0)):
            runner()
        samples: List[float] = []
        for _ in range(max(self.repeats, 1)):
            t0 = time.perf_counter()
            runner()
            samples.append(time.perf_counter() - t0)
        return pick_sample(samples, self.percentile)


@dataclass
class SyntheticTimer:
    """Closed-form fake clock: ``tasks * (overhead + iters * per_iter)``.

    Imbalance-aware (uses each task's true duration), dependency-aware when
    ``seconds_per_dependency`` is set, and — in its default configuration —
    independent of the backend: the same model ``tests/test_metg.py``
    builds points from by hand, so METG crossovers are exactly
    predictable: efficiency hits 50 % where ``iters *
    seconds_per_iteration == overhead_per_task``, i.e. at granularity
    ``2 * overhead_per_task``.

    Two study extensions consult the backend's deterministic-model hints
    (``Backend.sched_policy`` / ``Backend.comm_overlap``); both are off by
    default, in which case the backend is never instantiated:

    ``workers > 1``
        Compute time becomes the sum of per-wavefront makespans under the
        backend's scheduling policy (``core.schedule``): static column
        ownership pays the slowest block, work stealing re-packs greedily
        — the paper's §V-G imbalance-mitigation axis.  A backend that
        declares its own pool size (``HostBackend.workers``) overrides
        ``workers``, so the charged makespan always models the schedule
        the executor actually computed.

    ``seconds_per_byte > 0`` or ``seconds_per_rendezvous > 0``
        Each dependency moves ``output_bytes`` of payload; the per-graph
        communication term is ``ndeps * (seconds_per_dependency +
        output_bytes * seconds_per_byte)``.  Backends that double-buffer
        (``comm_overlap``) hide it behind compute — ``max(compute,
        comm)`` — while blocking backends pay ``compute + comm`` — the
        paper's §V-F communication-hiding axis.

        ``seconds_per_rendezvous`` models the two-sided matching cost: a
        per-dependency surcharge paid by every *rendezvous* comm mode
        (the sender and receiver must meet at a collective, so each
        message carries the synchronization latency).  One-sided
        backends (``Backend.comm == "onesided"``) skip it — a put/signal
        pair has no rendezvous — and their comm term is *always*
        overlappable (``max(compute, comm)``): the producer's put
        returns immediately and the consumer only spins on the signal
        word when the data hasn't already landed.

    Backends whose class declares ``dispatch_model = "per-launch"`` (the
    fused megakernel) are charged a *per-launch* model instead: one
    ``overhead_per_launch`` for the whole batch plus a small in-kernel
    ``fused_overhead_per_task`` (grid-step + table-indexing cost) per
    task, and no per-message comm term (dependencies are VMEM reads
    inside the launch).  Resolution is by name only
    (``backend_dispatch_model``) — no instantiation — so the default
    path still never touches a backend.  With the default constants the
    fused METG floor sits ~50x below the per-task floor, which is the
    undercut the committed ``BENCH_metg.pallas-fused.*`` baselines pin.

    ``ranks >= 1``
        The deterministic *rank-count* model behind the ``metg_scaling``
        weak-scaling family (``repro.bench.scaling``); 0 (the default)
        leaves it off.  Columns are owned in contiguous static blocks
        (``core.schedule.static_owners``, matching the ``CommPlan``
        shard layout), each wavefront's compute is the slowest rank's
        block, and only *cross-rank* dependencies pay the per-message
        term (intra-rank payloads are local reads) — at ``ranks=1``
        everything is local, so the weak-scaling reference ``T(1)`` is
        communication-free by construction, the same model family the
        ``n``-rank cells are charged (never the single-rank all-deps
        comm model above, which would inflate the reference).  Comm-mode
        hints resolve by spec string alone (``backend_comm_hints``) —
        never by instantiation — so the charged wall time is a pure
        function of ``(graph, ranks, spec)`` and the committed
        rank-{1,2,4,8} baselines are machine- and device-count-
        independent.  Per-launch backends divide their task term by
        ``ranks`` instead (one persistent kernel per rank, no message
        cost in the model — the documented idealization).
    """

    overhead_per_task: float = 20e-6
    seconds_per_iteration: float = 50e-9
    seconds_per_dependency: float = 0.0
    seconds_per_byte: float = 0.0
    seconds_per_rendezvous: float = 0.0
    workers: int = 1
    overhead_per_launch: float = 100e-6
    fused_overhead_per_task: float = 400e-9
    ranks: int = 0  # 0 = rank model off; >= 1 charges the scaling model
    name: str = field(default="synthetic", init=False)
    _backends: Dict[str, object] = field(default_factory=dict, repr=False)

    def _compute_seconds(self, g: TaskGraph, policy: str,
                         workers: int) -> float:
        if workers <= 1 or policy == "serial":
            return (g.num_tasks * self.overhead_per_task
                    + g.total_iterations() * self.seconds_per_iteration)
        from ..core.schedule import wavefront_makespan

        wall = 0.0
        for t in range(g.height):
            costs = [self.overhead_per_task
                     + g.task_iterations(t, i) * self.seconds_per_iteration
                     for i in range(g.width)]
            wall += wavefront_makespan(costs, workers, policy)
        return wall

    def _comm_seconds(self, g: TaskGraph, onesided: bool = False) -> float:
        per_dep = (self.seconds_per_dependency
                   + g.output_bytes * self.seconds_per_byte)
        if not onesided:
            per_dep += self.seconds_per_rendezvous
        if per_dep <= 0:
            return 0.0
        return int(g.dependence_matrices().sum()) * per_dep

    def _ranked_seconds(self, g: TaskGraph, onesided: bool,
                        overlap: bool) -> float:
        """Multi-rank weak-scaling model: block-owned compute, cross-rank
        messages only (see the ``ranks > 1`` section of the class doc)."""
        import numpy as np

        from ..core.schedule import static_owners, wavefront_makespan

        compute = 0.0
        for t in range(g.height):
            costs = [self.overhead_per_task
                     + g.task_iterations(t, i) * self.seconds_per_iteration
                     for i in range(g.width)]
            compute += wavefront_makespan(costs, self.ranks, "static")
        owners = static_owners(g.width, self.ranks)
        cross = (g.dependence_matrices()
                 & (owners[None, :, None] != owners[None, None, :]))
        per_dep = (self.seconds_per_dependency
                   + g.output_bytes * self.seconds_per_byte)
        if not onesided:
            per_dep += self.seconds_per_rendezvous
        comm = int(np.asarray(cross).sum()) * max(per_dep, 0.0)
        return max(compute, comm) if (overlap or onesided) else compute + comm

    def measure(self, backend_name: str, graphs: Sequence[TaskGraph]) -> float:
        # "auto" is the planner, not a cost model: resolve it to the
        # tuning table's winner first (a pure lookup — tuner.auto_resolve
        # uses ndev=1 here so fake-clock artifacts stay machine-
        # independent) and charge THAT backend's model.  Non-auto specs
        # pass through unchanged, so the default path stays backend-free.
        from .tuner import auto_resolve

        backend_name = auto_resolve(backend_name, graphs)
        if backend_dispatch_model(backend_name) == "per-launch":
            # one launch for the whole batch (the stacked grid covers all
            # graphs); dependencies are in-kernel refs, so no comm term.
            # ranks > 1 runs one persistent kernel per rank, so the task
            # term is divided across the rank count
            return self.overhead_per_launch + sum(
                g.num_tasks * self.fused_overhead_per_task
                + g.total_iterations() * self.seconds_per_iteration
                for g in graphs) / max(1, self.ranks)
        if self.ranks >= 1:
            onesided, overlap = backend_comm_hints(backend_name)
            return sum(self._ranked_seconds(g, onesided, overlap)
                       for g in graphs)
        policy, overlap, workers = "serial", False, self.workers
        onesided = False
        if (self.workers > 1 or self.seconds_per_byte > 0
                or self.seconds_per_rendezvous > 0):
            be = cached_backend(self._backends, backend_name)
            policy = getattr(be, "sched_policy", "static")
            overlap = bool(getattr(be, "comm_overlap", False))
            onesided = getattr(be, "comm", "auto") == "onesided"
            workers = int(getattr(be, "workers", self.workers))
        wall = 0.0
        for g in graphs:
            compute = self._compute_seconds(g, policy, workers)
            comm = self._comm_seconds(g, onesided)
            wall += (max(compute, comm) if overlap or onesided
                     else compute + comm)
        return wall


@dataclass
class DryRunTimer:
    """Roofline cost model over the backend's compiled HLO.

    Requires a backend that exposes its compiled programs
    (``Backend.lowered_hlo``); host-dynamic dispatch has no whole-graph
    program and is not supported.  ``dispatch_overhead_s`` charges a fixed
    launch cost per compiled program (per-graph programs pay it per graph).
    """

    dispatch_overhead_s: float = 0.0
    name: str = field(default="dryrun", init=False)
    _backends: Dict[str, object] = field(default_factory=dict, repr=False)

    def measure(self, backend_name: str, graphs: Sequence[TaskGraph]) -> float:
        from ..launch.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                       analyze_hlo)

        texts = cached_backend(self._backends, backend_name).lowered_hlo(graphs)
        if not texts:
            raise ValueError(
                f"backend {backend_name!r} does not expose compiled HLO; "
                "the dry-run timer needs a whole-graph program "
                "(use wallclock or synthetic timers instead)")
        # programs execute back-to-back, so each one's *own* binding term
        # is summed (max-of-sums would let one program's compute hide
        # another's communication)
        wall = 0.0
        for text in texts:
            a = analyze_hlo(text)
            wall += max(a["flops"] / PEAK_FLOPS,
                        a["hbm_bytes"] / HBM_BW,
                        a["collectives"]["total"] / LINK_BW)
        return max(wall, 1e-12) + self.dispatch_overhead_s * len(texts)
