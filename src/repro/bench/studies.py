"""Communication-hiding and load-imbalance studies (paper §V-F/G).

Task Bench's headline analyses beyond raw METG are each system's ability
to *hide communication* and to *mitigate load imbalance*.  This module
turns ``ScenarioSpec``'s payload and imbalance axes into those two
curves as first-class scenario families:

``metg_payload``
    Payload-bytes sweep at fixed task granularity, per backend with
    ``comm_overlap`` off ("blocking", strict MPI-style alternation), on
    ("overlap", double-buffered), and with one-sided put/signal
    communication ("onesided", no rendezvous at all) — the paper
    Fig. 11/12 analogue extended with the third point of the
    communication-hiding spectrum.

``metg_imbalance``
    Imbalance-factor sweep for ``host-dynamic`` with its static column
    schedule vs the work-stealing schedule — the paper Fig. 12/13
    analogue.

Every study cell is an ordinary single-point ``ScenarioSpec`` (fixed
iteration count, so the elapsed time *is* the study observable), runs
through ``run_scenario``/``BenchContext`` like any other scenario, and
emits the same schema-checked ``BENCH_<scenario>.json``.  Scenario names
put the family first (``metg_payload.<backend>.<variant>.bytes<N>``) so
the ``--baseline`` differ's family scoping covers them.

Derived metrics
---------------

overlap efficiency
    ``ideal / observed`` elapsed, where the ideal is the same variant's
    elapsed at the smallest swept payload (the communication-light
    reference).  1.0 means the extra payload bytes are fully hidden.

mitigation factor
    ``observed rate / self-balanced rate`` — the fraction of its own
    balanced (imbalance=0) throughput a schedule retains under
    imbalance.  Higher is better; a perfect dynamic scheduler holds the
    wavefront mean, a static one pays the slowest block.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Mapping, Tuple

from .scenario import ScenarioSpec, SweepControls
from .sweep import ScenarioResult
from .timers import SyntheticTimer, Timer

# the swept axes (chosen so the synthetic model's communication term
# crosses its compute term inside the payload sweep, and so imbalance=2.0
# saturates the duration floor for a visibly heterogeneous wavefront)
PAYLOAD_BYTES: Tuple[int, ...] = (16, 4096, 65536)
IMBALANCE_FACTORS: Tuple[float, ...] = (0.5, 1.0, 1.5, 2.0)

# study constants: one fixed granularity (survives the smoke ceiling of
# 64 iterations, so CI baselines measure the same point), a worker pool
# for the scheduling model, and fake-clock rates that put the interesting
# crossover inside the swept ranges
STUDY_ITERATIONS = 64
STUDY_WORKERS = 4
SECONDS_PER_BYTE = 4e-9
# rendezvous surcharge: what blocking/overlap pay per message for the
# two-sided match (the one-sided variant's entire advantage in the model)
SECONDS_PER_RENDEZVOUS = 2e-6
# imbalance study: per-iteration work must dominate the dispatch overhead
# or every wavefront is overhead-bound and no schedule can differentiate
IMBALANCE_SECONDS_PER_ITERATION = 2e-6

PAYLOAD_VARIANTS = ("blocking", "overlap", "onesided")
IMBALANCE_VARIANTS = ("static", "steal")

# what degenerate metric inputs (zero / negative / non-finite elapsed or
# rate, e.g. a smoke run too small to time) collapse to instead of
# raising or emitting inf — 0.0 reads as "no efficiency/mitigation
# observed" and keeps downstream artifact arithmetic finite
DEGENERATE_METRIC = 0.0


def payload_spec(backend: str = "shardmap-csp", comm_overlap: bool = False,
                 output_bytes: int = 16,
                 variant: str | None = None) -> ScenarioSpec:
    """One ``metg_payload`` cell: fixed granularity, one payload size.

    ``variant`` selects the comm mode ("blocking" / "overlap" /
    "onesided"); when omitted it is derived from ``comm_overlap`` for
    backward compatibility with two-variant callers.
    """
    if variant is None:
        variant = "overlap" if comm_overlap else "blocking"
    if variant not in PAYLOAD_VARIANTS:
        raise ValueError(f"unknown payload variant {variant!r}; "
                         f"expected one of {PAYLOAD_VARIANTS}")
    spec = (f"{backend}[comm=onesided]" if variant == "onesided"
            else f"{backend}[comm_overlap={variant == 'overlap'}]")
    return ScenarioSpec(
        name=f"metg_payload.{backend}.{variant}.bytes{output_bytes}",
        backend=spec,
        pattern="stencil",
        width=8,
        height=16,
        output_bytes=output_bytes,
        sweep=SweepControls(schedule=(STUDY_ITERATIONS,), repeats=3),
    )


def imbalance_spec(schedule: str = "static",
                   imbalance: float = 0.0) -> ScenarioSpec:
    """One ``metg_imbalance`` cell: fixed granularity, one imbalance."""
    return ScenarioSpec(
        name=f"metg_imbalance.host-dynamic.{schedule}.imb{imbalance}",
        backend=f"host-dynamic[schedule={schedule},workers={STUDY_WORKERS}]",
        pattern="stencil",
        width=8,
        height=16,
        imbalance=imbalance,
        sweep=SweepControls(schedule=(STUDY_ITERATIONS,), repeats=3),
    )


def payload_study_specs(backend: str = "shardmap-csp") -> List[ScenarioSpec]:
    """Every ``metg_payload`` cell for one backend, one block per variant
    (blocking, overlap, onesided)."""
    return [payload_spec(backend, output_bytes=ob, variant=v)
            for v in PAYLOAD_VARIANTS for ob in PAYLOAD_BYTES]


def imbalance_study_specs() -> List[ScenarioSpec]:
    """Every ``metg_imbalance`` cell: balanced baseline + the sweep,
    for the static and stealing schedules."""
    return [imbalance_spec(schedule=s, imbalance=f)
            for s in IMBALANCE_VARIANTS
            for f in (0.0,) + IMBALANCE_FACTORS]


def study_timer(timer: Timer | None, *, workers: int = 1,
                seconds_per_byte: float = 0.0,
                seconds_per_rendezvous: float = 0.0,
                seconds_per_iteration: float | None = None) -> Timer | None:
    """Specialize a ``SyntheticTimer`` with study knobs.

    Other timers (wall clock, dry run, user-defined) pass through
    unchanged — the studies are then real measurements and the synthetic
    knobs are irrelevant.
    """
    if not isinstance(timer, SyntheticTimer):
        return timer
    changes: Dict[str, object] = {
        "workers": workers,
        "seconds_per_byte": seconds_per_byte,
        "seconds_per_rendezvous": seconds_per_rendezvous,
    }
    if seconds_per_iteration is not None:
        changes["seconds_per_iteration"] = seconds_per_iteration
    return dataclasses.replace(timer, **changes)


def _single_point(result: ScenarioResult):
    """The study cell's one fixed-granularity sweep point."""
    if len(result.points) != 1:
        raise ValueError(
            f"study scenarios measure exactly one granularity, got "
            f"{len(result.points)} points for {result.spec.name!r}")
    return result.points[0]


def elapsed_s(result: ScenarioResult) -> float:
    """The study cell's elapsed seconds."""
    return _single_point(result).wall_time


def observed_rate(result: ScenarioResult) -> float:
    """The study cell's useful-work rate (work / elapsed)."""
    return _single_point(result).rate


def _guarded_ratio(num: float, den: float) -> float:
    """``num / den`` clamped to finite: degenerate inputs (zero, negative,
    NaN or inf — e.g. a smoke run too small to register any elapsed time)
    collapse to ``DEGENERATE_METRIC`` instead of raising or propagating
    inf into artifacts, where the schema check would reject them."""
    if (not math.isfinite(num) or not math.isfinite(den)
            or num <= 0 or den <= 0):
        return DEGENERATE_METRIC
    ratio = num / den
    return ratio if math.isfinite(ratio) else DEGENERATE_METRIC


def overlap_efficiency(ideal_s: float, observed_s: float) -> float:
    """``ideal / observed``: 1.0 when added communication is fully hidden.

    Degenerate inputs clamp to ``DEGENERATE_METRIC`` (see
    ``_guarded_ratio``) so study arithmetic never emits NaN/inf.
    """
    return _guarded_ratio(ideal_s, observed_s)


def mitigation_factor(balanced_rate: float, observed_rate: float) -> float:
    """``observed / self-balanced`` rate: imbalance throughput retained.

    Degenerate inputs clamp to ``DEGENERATE_METRIC`` (see
    ``_guarded_ratio``) so study arithmetic never emits NaN/inf.
    """
    return _guarded_ratio(observed_rate, balanced_rate)


@dataclass(frozen=True)
class StudyPoint:
    """One derived curve point: (x, variant) -> elapsed/rate + metric."""

    x: float          # payload bytes / imbalance factor
    variant: str      # "blocking"/"overlap" or "static"/"steal"
    elapsed_s: float
    rate: float
    metric: float     # overlap efficiency / mitigation factor


def payload_curve(
    results: Mapping[Tuple[int, str], ScenarioResult],
) -> List[StudyPoint]:
    """Overlap-efficiency curve from ``{(bytes, variant): result}``.

    Each variant normalizes against its own smallest-payload elapsed (the
    communication-light ideal), so the two curves are directly
    comparable: the overlap variant decaying slower *is* communication
    hiding.
    """
    points: List[StudyPoint] = []
    for variant in PAYLOAD_VARIANTS:
        sizes = sorted(b for b, v in results if v == variant)
        if not sizes:
            continue
        ideal = elapsed_s(results[(sizes[0], variant)])
        for b in sizes:
            res = results[(b, variant)]
            obs = elapsed_s(res)
            points.append(StudyPoint(
                x=float(b), variant=variant, elapsed_s=obs,
                rate=observed_rate(res),
                metric=overlap_efficiency(ideal, obs)))
    return points


def mitigation_curve(
    results: Mapping[Tuple[float, str], ScenarioResult],
) -> List[StudyPoint]:
    """Mitigation-factor curve from ``{(imbalance, variant): result}``.

    Each variant needs its own imbalance=0.0 cell (the self-balanced
    baseline the factor normalizes against).
    """
    points: List[StudyPoint] = []
    for variant in IMBALANCE_VARIANTS:
        factors = sorted(f for f, v in results if v == variant)
        if not factors:
            continue
        if factors[0] != 0.0:
            raise ValueError(
                f"mitigation needs the balanced (imbalance=0.0) baseline "
                f"for {variant!r}; have factors {factors}")
        balanced = observed_rate(results[(0.0, variant)])
        for f in factors:
            res = results[(f, variant)]
            rate = observed_rate(res)
            points.append(StudyPoint(
                x=f, variant=variant, elapsed_s=elapsed_s(res), rate=rate,
                metric=mitigation_factor(balanced, rate)))
    return points
