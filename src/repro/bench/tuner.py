"""Self-tuning backend planner: cutouts, mode-space sweeps, tuning tables.

The paper's core finding is that no single runtime wins everywhere —
which backend is fastest flips with task granularity, dependence
pattern, payload size, and device count (§V).  This module closes that
loop DaCe-cutout-tuner style:

cutout
    ``graphs_cutout``/``spec_cutout`` reduce a concrete workload to its
    *tuning key* ``(pattern, granularity bucket, payload bucket, ndev,
    ngraphs)`` — the coordinates the paper's winner actually flips on.

sweep driver
    ``build_tuning_table`` enumerates the legal backend/mode space
    (``enumerate_mode_space``: every registered backend x the known
    schedule/comm/overlap options from its constructor signature,
    illegal combos pruned by the constructors themselves) and times
    each candidate on a representative corpus cell with the existing
    ``Timer`` protocol.  ``SyntheticTimer`` by default, so tuning is
    deterministic and ~free; wall-clock is opt-in
    (``benchmarks/run.py --tune --timer wallclock``).

tuning table
    A schema-checked, committed artifact
    (``benchmarks/tuning/TUNE_default.json``), versioned and validated
    like ``BENCH_*.json``: one entry per tuning key recording the
    winning canonical backend spec, its elapsed time, the measured
    margin over the best strictly-slower alternative, and the full
    candidate timing list.  Regenerate with::

        python -m benchmarks.run --tune --timer synthetic \
            --artifacts benchmarks/tuning

dispatch
    ``get_backend("auto")`` (``repro.backends.auto``) consults the
    table at dispatch time via ``TuningTable.resolve`` — exact key
    first, then nearest bucket within the same (pattern, ndev,
    ngraphs), then nearest same-pattern key, then the documented
    fallback (``DEFAULT_FALLBACK``).  Zero per-dispatch measurement:
    resolution is a pure table lookup.
"""
from __future__ import annotations

import itertools
import json
import math
import os
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.graph import TaskGraph, make_graph, replicate
from .artifact import SCHEMA_VERSION, _typed

# ---------------------------------------------------------------- buckets

# Mean iterations per task.  fine < 16 <= medium < 256 <= coarse: the
# synthetic model's 50% METG crossover sits at iterations ~400 (where
# iters * 50ns == 20us), so "fine" is deep in dispatch-bound territory,
# "coarse" approaches compute-bound, and "medium" straddles the study
# granularity (STUDY_ITERATIONS = 64).
GRANULARITY_BUCKETS: Tuple[str, ...] = ("fine", "medium", "coarse")
GRANULARITY_EDGES: Tuple[float, ...] = (16.0, 256.0)
GRANULARITY_REPRESENTATIVE: Dict[str, int] = {
    "fine": 1, "medium": 64, "coarse": 1024}

# Payload bytes per dependency.  small < 1 KiB <= medium < 32 KiB <=
# large, bracketing studies.PAYLOAD_BYTES = (16, 4096, 65536).
PAYLOAD_BUCKETS: Tuple[str, ...] = ("small", "medium", "large")
PAYLOAD_EDGES: Tuple[int, ...] = (1024, 32768)
PAYLOAD_REPRESENTATIVE: Dict[str, int] = {
    "small": 16, "medium": 4096, "large": 65536}

# what ``auto`` dispatches when the table has no usable key (or no table
# is present at all): the vectorized single-device backend that runs
# every pattern on every runtime with no mode prerequisites
DEFAULT_FALLBACK = "xla-scan"


def granularity_bucket(mean_iterations: float) -> str:
    """The granularity bucket of a mean per-task iteration count."""
    if not math.isfinite(mean_iterations) or mean_iterations < 0:
        raise ValueError(
            f"mean_iterations must be finite and >= 0, got {mean_iterations!r}")
    for bucket, edge in zip(GRANULARITY_BUCKETS, GRANULARITY_EDGES):
        if mean_iterations < edge:
            return bucket
    return GRANULARITY_BUCKETS[-1]


def payload_bucket(output_bytes: int) -> str:
    """The payload bucket of a per-dependency output size."""
    if output_bytes < 0:
        raise ValueError(f"output_bytes must be >= 0, got {output_bytes!r}")
    for bucket, edge in zip(PAYLOAD_BUCKETS, PAYLOAD_EDGES):
        if output_bytes < edge:
            return bucket
    return PAYLOAD_BUCKETS[-1]


# ------------------------------------------------------------ tuning key

_KEY_FIELDS: Dict[str, type] = {
    "pattern": str,
    "granularity": str,
    "payload": str,
    "ndev": int,
    "ngraphs": int,
}


@dataclass(frozen=True)
class TuningKey:
    """One cell of the tuning space — what the winner flips on."""

    pattern: str
    granularity: str
    payload: str
    ndev: int = 1
    ngraphs: int = 1

    def __post_init__(self):
        if self.granularity not in GRANULARITY_BUCKETS:
            raise ValueError(
                f"unknown granularity bucket {self.granularity!r}; "
                f"known: {GRANULARITY_BUCKETS}")
        if self.payload not in PAYLOAD_BUCKETS:
            raise ValueError(
                f"unknown payload bucket {self.payload!r}; "
                f"known: {PAYLOAD_BUCKETS}")
        if not self.pattern:
            raise ValueError("tuning key needs a pattern")
        if self.ndev < 1 or self.ngraphs < 1:
            raise ValueError("ndev and ngraphs must be >= 1")

    def to_dict(self) -> Dict[str, object]:
        return {"pattern": self.pattern, "granularity": self.granularity,
                "payload": self.payload, "ndev": self.ndev,
                "ngraphs": self.ngraphs}


def key_order(key: TuningKey) -> Tuple:
    """Deterministic sort order for table entries and diff output."""
    return (key.pattern, GRANULARITY_BUCKETS.index(key.granularity),
            PAYLOAD_BUCKETS.index(key.payload), key.ndev, key.ngraphs)


def key_slug(key: TuningKey) -> str:
    """Compact printable form: ``stencil.fine.small.d1.g1``."""
    return (f"{key.pattern}.{key.granularity}.{key.payload}"
            f".d{key.ndev}.g{key.ngraphs}")


def graphs_cutout(graphs: Sequence[TaskGraph], ndev: int = 1) -> TuningKey:
    """Reduce a concrete workload (the graphs a backend is about to run)
    to its tuning key.

    Pattern and payload come from the first graph (a heterogeneous batch
    tunes on its leading graph — the nearest single key the table can
    hold); granularity is the batch-wide mean iterations per task, so an
    imbalanced graph lands in the bucket of its *average* task.
    """
    graphs = list(graphs)
    if not graphs:
        raise ValueError("cutout needs at least one graph")
    total_tasks = sum(g.num_tasks for g in graphs)
    total_iters = sum(g.total_iterations() for g in graphs)
    mean_iters = total_iters / max(total_tasks, 1)
    return TuningKey(
        pattern=graphs[0].pattern,
        granularity=granularity_bucket(mean_iters),
        payload=payload_bucket(graphs[0].output_bytes),
        ndev=max(int(ndev), 1),
        ngraphs=len(graphs))


def spec_cutout(spec, ndev: int = 1) -> TuningKey:
    """The tuning key of a single-point ``ScenarioSpec``.

    A multi-point sweep spans several granularity buckets — each point
    resolves separately at dispatch time — so the spec-level cutout only
    exists for fixed-granularity specs (the study families).
    """
    schedule = spec.sweep.iteration_schedule()
    if len(schedule) != 1:
        raise ValueError(
            f"spec_cutout needs a single-point sweep (one granularity is "
            f"one tuning key); {spec.name!r} sweeps {schedule} — cut out "
            f"one point, or use graphs_cutout on that point's graphs")
    return graphs_cutout(spec.resolved().graphs(schedule[0]), ndev=ndev)


# ------------------------------------------------- mode-space enumeration

# the mode axes the paper studies (backend x schedule x comm x overlap);
# each backend only sweeps the axes its constructor actually accepts
# (backend_option_signature), and values equal to the constructor default
# collapse into the bare name so the canonical rendering is unique
_MODE_SPACE: Dict[str, Tuple[object, ...]] = {
    "schedule": ("static", "steal"),
    "comm": ("auto", "onesided"),
    "comm_overlap": (False, True),
}


def backend_mode_specs(name: str) -> List[str]:
    """The legal canonical mode specs of one registered backend.

    Intersects ``_MODE_SPACE`` with the backend's known-options metadata
    (its constructor signature), then prunes combos the constructor
    rejects — e.g. ``pallas-fused[comm=auto]`` (the megakernel only
    accepts one-sided or no comm mode) never becomes a candidate.
    """
    from ..backends.base import (backend_option_signature,
                                 canonical_backend_spec, get_backend)

    sig = backend_option_signature(name)
    axes = [k for k in _MODE_SPACE if sig is not None and k in sig]
    specs = {name}
    for combo in itertools.product(*(_MODE_SPACE[k] for k in axes)):
        kwargs = {k: v for k, v in zip(axes, combo) if v != sig[k]}
        if not kwargs:
            continue  # all-defaults combo == the bare name
        opts = ",".join(f"{k}={v}" for k, v in sorted(kwargs.items()))
        spec = canonical_backend_spec(f"{name}[{opts}]")
        try:
            get_backend(spec)
        except (ValueError, KeyError):
            continue  # the constructor vetoed the combo: not legal
        specs.add(spec)
    return sorted(specs)


def enumerate_mode_space() -> List[str]:
    """Every legal candidate spec: all registered backends x their modes.

    ``auto`` itself is excluded — the planner never times itself.
    """
    from ..backends.base import backend_names

    out: List[str] = []
    for name in backend_names():
        if name == "auto":
            continue
        out.extend(backend_mode_specs(name))
    return sorted(out)


# ------------------------------------------------------- tuning corpus

# full-grid patterns: the three dependence shapes the committed bench
# corpus sweeps (stencil/nearest/spread cover halo, ring and allgather
# comm planning); the reduced smoke grid keeps stencil only
TUNE_PATTERNS: Tuple[str, ...] = ("stencil", "nearest", "spread")
SMOKE_PATTERNS: Tuple[str, ...] = ("stencil",)
_TUNE_WIDTH = 8
_TUNE_HEIGHT = 16
_SMOKE_HEIGHT = 8


@dataclass(frozen=True)
class TuningCell:
    """One corpus cell: a tuning key, its family, the candidate specs to
    race, and the representative graphs they race on."""

    key: TuningKey
    family: str
    candidates: Tuple[str, ...]
    graphs: Tuple[TaskGraph, ...]


def _comm_candidates() -> Tuple[str, ...]:
    """The communication-mode spectrum the payload cells race: blocking
    (bare), double-buffered overlap, and one-sided put/signal, on both
    SPMD backends.  The fused megakernel is excluded here on purpose:
    its synthetic per-launch model carries no per-message comm term, so
    racing it in a communication study would be a model artifact, not a
    comm-mode comparison."""
    out: List[str] = []
    for b in ("shardmap-csp", "shardmap-pipeline"):
        out.extend((b, f"{b}[comm_overlap=True]", f"{b}[comm=onesided]"))
    return tuple(sorted(out))


def tuning_corpus(smoke: bool = False) -> List[TuningCell]:
    """The representative cells the sweep driver races candidates on.

    One cell per (pattern x granularity bucket) at small payload plus a
    task-parallelism cell (the ``metg`` family's axes), and one cell per
    larger payload bucket at the study granularity (the ``metg_payload``
    family's axis).  ``smoke=True`` is the reduced CI grid: a strict
    subset of the full grid's keys (same buckets, shallower graphs), so
    the smoke table diffs cleanly against the committed full table.
    """
    mode_space = tuple(enumerate_mode_space())
    height = _SMOKE_HEIGHT if smoke else _TUNE_HEIGHT
    cells: List[TuningCell] = []
    grans = ("fine", "medium") if smoke else GRANULARITY_BUCKETS
    for pattern in (SMOKE_PATTERNS if smoke else TUNE_PATTERNS):
        for gran in grans:
            g = make_graph(width=_TUNE_WIDTH, height=height, pattern=pattern,
                           kernel="compute",
                           iterations=GRANULARITY_REPRESENTATIVE[gran],
                           output_bytes=PAYLOAD_REPRESENTATIVE["small"])
            cells.append(TuningCell(
                key=TuningKey(pattern, gran, "small"),
                family="metg", candidates=mode_space, graphs=(g,)))
    if not smoke:
        # task parallelism (paper Fig 9d): 4 concurrent fine graphs
        g = make_graph(width=_TUNE_WIDTH, height=height, pattern="nearest",
                       kernel="compute", iterations=1,
                       output_bytes=PAYLOAD_REPRESENTATIVE["small"])
        cells.append(TuningCell(
            key=TuningKey("nearest", "fine", "small", ngraphs=4),
            family="metg", candidates=mode_space,
            graphs=tuple(replicate(g, 4))))
    comm = _comm_candidates()
    for pb in (("large",) if smoke else ("medium", "large")):
        g = make_graph(width=_TUNE_WIDTH, height=height, pattern="stencil",
                       kernel="compute",
                       iterations=GRANULARITY_REPRESENTATIVE["medium"],
                       output_bytes=PAYLOAD_REPRESENTATIVE[pb])
        cells.append(TuningCell(
            key=TuningKey("stencil", "medium", pb),
            family="metg_payload", candidates=comm, graphs=(g,)))
    seen = set()
    for cell in cells:
        if cell.key in seen:
            raise ValueError(f"tuning corpus has duplicate key "
                             f"{key_slug(cell.key)}")
        seen.add(cell.key)
    return cells


def _cell_timer(base_timer, family: str):
    """The timer a family's cells race on.  ``metg_payload`` specializes
    the synthetic clock with the study's byte/rendezvous rates (the same
    knobs ``bench_metg_payload`` measures with) so the comm modes are
    distinguishable; non-synthetic timers pass through unchanged."""
    if family == "metg_payload":
        from .studies import (SECONDS_PER_BYTE, SECONDS_PER_RENDEZVOUS,
                              study_timer)

        return study_timer(base_timer, seconds_per_byte=SECONDS_PER_BYTE,
                           seconds_per_rendezvous=SECONDS_PER_RENDEZVOUS)
    return base_timer


# ------------------------------------------------------- sweep driver

def build_tuning_table(timer=None, smoke: bool = False) -> Dict:
    """Race every candidate on every corpus cell; returns the validated
    tuning-table document.

    Ties break deterministically on the canonical spec string, so the
    bare/base spelling of a mode family wins over its no-op variants.
    ``margin`` is the relative cost of the best *strictly slower*
    alternative — "what you lose by picking the next-best distinct
    choice" — and 0.0 when every candidate ties.
    """
    from .timers import SyntheticTimer, timer_config

    if timer is None:
        timer = SyntheticTimer()
    entries: List[Dict] = []
    for cell in tuning_corpus(smoke=smoke):
        cell_timer = _cell_timer(timer, cell.family)
        timed = sorted(
            (float(cell_timer.measure(spec, list(cell.graphs))), spec)
            for spec in cell.candidates)
        best, winner = timed[0]
        if not (math.isfinite(best) and best > 0):
            # a candidate timing 0 (or NaN) cannot be ranked — surface
            # the cell, don't let the margin division or the schema
            # check produce a less-specific error downstream
            raise ValueError(
                f"candidate {winner!r} timed {best!r}s at tuning cell "
                f"{key_slug(cell.key)}; tuning needs finite positive "
                f"times (wall-clock runs may need larger graphs)")
        slower = [t for t, _ in timed if t > best]
        margin = (min(slower) - best) / best if slower else 0.0
        entries.append({
            "key": cell.key.to_dict(),
            "family": cell.family,
            "winner": winner,
            "elapsed_s": best,
            "margin": margin,
            "candidates": [[spec, t] for t, spec in timed],
        })
    entries.sort(key=lambda e: key_order(TuningKey(**e["key"])))
    return validate_tuning_table({
        "schema": SCHEMA_VERSION,
        "kind": "tuning_table",
        "timer": timer.name,
        "timer_config": timer_config(timer),
        "entries": entries,
    })


# ------------------------------------------------- table schema + files

def validate_tuning_table(doc: Dict) -> Dict:
    """Schema check (raises ValueError); returns ``doc`` for chaining.

    Mirrors ``artifact.validate_artifact``: bools are not numbers,
    NaN/inf are corruption, unknown key fields are named, duplicate keys
    are rejected, and the winner must be a canonical spec drawn from the
    recorded candidate list.
    """
    from ..backends.base import canonical_backend_spec

    def need(cond, msg):
        if not cond:
            raise ValueError(f"invalid tuning table: {msg}")

    need(isinstance(doc, dict), "not an object")
    need(doc.get("schema") == SCHEMA_VERSION,
         f"schema must be {SCHEMA_VERSION}, got {doc.get('schema')!r}")
    need(doc.get("kind") == "tuning_table",
         f"kind must be 'tuning_table', got {doc.get('kind')!r}")
    need(isinstance(doc.get("timer"), str) and doc.get("timer"),
         f"timer must be a non-empty string, got {doc.get('timer')!r}")
    need(isinstance(doc.get("timer_config"), dict), "timer_config")
    entries = doc.get("entries")
    need(isinstance(entries, list) and entries,
         "entries must be a non-empty list")
    seen = set()
    for n, e in enumerate(entries):
        need(isinstance(e, dict), f"entries[{n}] not an object")
        key = e.get("key")
        need(isinstance(key, dict), f"entries[{n}].key missing")
        for k in key:
            need(k in _KEY_FIELDS,
                 f"entries[{n}].key has unknown field {k!r}; "
                 f"known: {sorted(_KEY_FIELDS)}")
        for k, t in _KEY_FIELDS.items():
            if t is str:
                need(isinstance(key.get(k), str) and key.get(k),
                     f"entries[{n}].key.{k} must be a non-empty string")
            else:
                need(_typed(key.get(k), int) and key[k] >= 1,
                     f"entries[{n}].key.{k} must be an int >= 1")
        need(key["granularity"] in GRANULARITY_BUCKETS,
             f"entries[{n}].key.granularity {key['granularity']!r} is not "
             f"a bucket; known: {GRANULARITY_BUCKETS}")
        need(key["payload"] in PAYLOAD_BUCKETS,
             f"entries[{n}].key.payload {key['payload']!r} is not a "
             f"bucket; known: {PAYLOAD_BUCKETS}")
        tk = TuningKey(**key)
        need(tk not in seen, f"duplicate tuning key {key_slug(tk)}")
        seen.add(tk)
        need(isinstance(e.get("family"), str) and e["family"],
             f"entries[{n}].family must be a non-empty string")
        need(_typed(e.get("margin"), (int, float)) and e["margin"] >= 0,
             f"entries[{n}].margin must be a finite number >= 0, "
             f"got {e.get('margin')!r}")
        need(_typed(e.get("elapsed_s"), (int, float)) and e["elapsed_s"] > 0,
             f"entries[{n}].elapsed_s must be a finite number > 0")
        cands = e.get("candidates")
        need(isinstance(cands, list) and cands,
             f"entries[{n}].candidates must be a non-empty list")
        specs = []
        for m, c in enumerate(cands):
            need(isinstance(c, (list, tuple)) and len(c) == 2,
                 f"entries[{n}].candidates[{m}] must be a [spec, seconds] "
                 f"pair")
            spec, t = c
            need(isinstance(spec, str) and spec,
                 f"entries[{n}].candidates[{m}] spec must be a non-empty "
                 f"string")
            need(_typed(t, (int, float)) and t > 0,
                 f"entries[{n}].candidates[{m}] seconds must be a finite "
                 f"number > 0")
            specs.append(spec)
        w = e.get("winner")
        need(isinstance(w, str) and w,
             f"entries[{n}].winner must be a non-empty string")
        try:
            canonical = canonical_backend_spec(w)
        except ValueError:
            need(False, f"entries[{n}].winner {w!r} is not a parseable "
                        f"backend spec")
        need(canonical == w, f"entries[{n}].winner {w!r} is not canonical "
                             f"(expected {canonical!r})")
        need(w in specs,
             f"entries[{n}].winner {w!r} is not among its candidates")
    return doc


def tuning_table_path(outdir: str, slug: str = "default") -> str:
    """Where ``write_tuning_json`` puts a table: ``TUNE_<slug>.json``."""
    return os.path.join(outdir, f"TUNE_{slug}.json")


def write_tuning_json(doc: Dict, outdir: str, slug: str = "default") -> str:
    """Write a validated tuning table atomically; returns the path."""
    validate_tuning_table(doc)
    os.makedirs(outdir, exist_ok=True)
    path = tuning_table_path(outdir, slug)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


def read_tuning_json(path: str) -> Dict:
    """Read + schema-check one tuning table.

    Truncated or garbage files raise ``ValueError`` naming the path (not
    a bare ``JSONDecodeError``) — same contract as ``read_bench_json``.
    """
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"invalid tuning table: {path} is not valid JSON "
                f"(truncated or garbage: {e})") from e
    return validate_tuning_table(doc)


def tuning_json_names(dirpath: str) -> List[str]:
    """Sorted TUNE_*.json filenames under ``dirpath``."""
    return sorted(f for f in os.listdir(dirpath)
                  if f.startswith("TUNE_") and f.endswith(".json"))


# ------------------------------------------------------- resolution

class TuningTable:
    """A validated tuning table with nearest-key resolution."""

    def __init__(self, doc: Dict, path: Optional[str] = None):
        self.doc = validate_tuning_table(doc)
        self.path = path
        self._entries: Dict[TuningKey, Dict] = {
            TuningKey(**e["key"]): e for e in doc["entries"]}

    @property
    def timer(self) -> str:
        return self.doc["timer"]

    def keys(self) -> List[TuningKey]:
        return sorted(self._entries, key=key_order)

    def entry(self, key: TuningKey) -> Optional[Dict]:
        """Exact-key lookup only (no nearest-neighbor semantics)."""
        return self._entries.get(key)

    def resolve_entry(self, key: TuningKey) -> Optional[Dict]:
        """Nearest tuning entry, in three tiers.

        1. the exact key;
        2. same (pattern, ndev, ngraphs): the entry at minimum bucket
           distance (|Δgranularity index| + |Δpayload index|);
        3. same pattern only: minimum bucket distance, then nearest
           ngraphs, then nearest ndev.

        A different *pattern* is never substituted — the dependence
        shape changes which comm plan even exists — so a pattern the
        table has not seen resolves to ``None`` (callers fall back).
        All tie-breaks are deterministic (bucket indices, then the
        winner spec), so resolution is stable across runs.
        """
        if key in self._entries:
            return self._entries[key]
        gi = GRANULARITY_BUCKETS.index(key.granularity)
        pi = PAYLOAD_BUCKETS.index(key.payload)

        def bucket_dist(k: TuningKey) -> int:
            return (abs(GRANULARITY_BUCKETS.index(k.granularity) - gi)
                    + abs(PAYLOAD_BUCKETS.index(k.payload) - pi))

        def tie(k: TuningKey) -> Tuple:
            return (GRANULARITY_BUCKETS.index(k.granularity),
                    PAYLOAD_BUCKETS.index(k.payload),
                    self._entries[k]["winner"])

        same_shape = [k for k in self._entries
                      if k.pattern == key.pattern and k.ndev == key.ndev
                      and k.ngraphs == key.ngraphs]
        if same_shape:
            best = min(same_shape, key=lambda k: (bucket_dist(k),) + tie(k))
            return self._entries[best]
        same_pattern = [k for k in self._entries if k.pattern == key.pattern]
        if same_pattern:
            best = min(same_pattern,
                       key=lambda k: (bucket_dist(k),
                                      abs(k.ngraphs - key.ngraphs),
                                      abs(k.ndev - key.ndev)) + tie(k))
            return self._entries[best]
        return None

    def resolve(self, key: TuningKey) -> Optional[str]:
        """The winning backend spec for ``key``, or None on a miss."""
        e = self.resolve_entry(key)
        return None if e is None else e["winner"]


def default_table_path() -> str:
    """The committed table's repo-layout location.  When the package is
    installed outside the repo this path simply does not exist and
    ``load_tuning_table(None)`` returns None (auto falls back)."""
    return str(Path(__file__).resolve().parents[3]
               / "benchmarks" / "tuning" / "TUNE_default.json")


_DEFAULT_CACHE: Dict[str, TuningTable] = {}


def load_tuning_table(path: Optional[str] = None) -> Optional[TuningTable]:
    """Load a tuning table.

    ``path=None`` loads the committed default (cached per process;
    returns None when absent — a checkout that never tuned still
    dispatches, on the fallback).  An *explicit* path must exist and
    validate: pointing ``auto[table=...]`` at a missing or corrupt file
    is a configuration error, not a silent fallback.
    """
    if path is None:
        p = default_table_path()
        if not os.path.exists(p):
            return None
        if p not in _DEFAULT_CACHE:
            _DEFAULT_CACHE[p] = TuningTable(read_tuning_json(p), path=p)
        return _DEFAULT_CACHE[p]
    if not os.path.exists(path):
        raise ValueError(
            f"tuning table {path!r} not found (auto[table=...] must name "
            f"an existing TUNE_*.json)")
    return TuningTable(read_tuning_json(path), path=path)


_AUTO_OPTIONS = ("fallback", "table", "timer")


def auto_resolve(spec: str, graphs: Sequence[TaskGraph],
                 ndev: int = 1) -> str:
    """Resolve an ``auto[...]`` spec string to a concrete backend spec.

    Pure table lookup — no backend is instantiated and nothing is
    measured — so ``SyntheticTimer`` calls this with ``ndev=1`` to keep
    the committed baselines machine-independent (the fake clock's model
    is single-device; ``AutoBackend`` itself resolves with the real
    device count).  Non-auto specs pass through unchanged.
    """
    from ..backends.base import parse_backend_spec

    base, kw = parse_backend_spec(spec)
    if base != "auto":
        return spec
    unknown = sorted(set(kw) - set(_AUTO_OPTIONS))
    if unknown:
        raise ValueError(
            f"backend 'auto' does not accept option {unknown[0]!r}; "
            f"known options: {list(_AUTO_OPTIONS)}")
    timer = kw.get("timer", "synthetic")
    fallback = kw.get("fallback", DEFAULT_FALLBACK)
    table = load_tuning_table(kw.get("table"))
    if table is not None and table.timer != timer:
        raise ValueError(
            f"tuning table {table.path or '<default>'} was tuned on timer "
            f"{table.timer!r} but auto asked for timer={timer!r}; retune "
            f"with `benchmarks.run --tune --timer {timer}` or point "
            f"table= at a matching table")
    if table is None:
        return fallback
    winner = table.resolve(graphs_cutout(graphs, ndev=ndev))
    return winner if winner is not None else fallback


# ------------------------------------------------------- table diffing

def diff_tuning_tables(baseline: Dict, current: Dict,
                       subset_ok: bool = False,
                       ) -> Tuple[List[str], List[str]]:
    """Diff two tuning tables; returns ``(fatal, notes)``.

    Fatal: timer mismatch (tunings are not comparable), a winner that
    changed at a shared key, and — unless ``subset_ok`` (the reduced
    smoke grid, whose keys are a strict subset of the full grid's) — a
    baseline key missing from the current table.  Notes: subset-skipped
    keys and keys new in the current table (non-fatal, like the bench
    gate's new-in-current scenarios).
    """
    fatal: List[str] = []
    notes: List[str] = []
    bt, ct = baseline.get("timer"), current.get("timer")
    if bt != ct:
        fatal.append(f"timer changed: baseline {bt!r} vs current {ct!r} "
                     f"(tunings are not comparable)")
        return fatal, notes
    base = {TuningKey(**e["key"]): e for e in baseline["entries"]}
    cur = {TuningKey(**e["key"]): e for e in current["entries"]}
    for k in sorted(base, key=key_order):
        ce = cur.get(k)
        if ce is None:
            if subset_ok:
                notes.append(f"tuning key {key_slug(k)} not retuned "
                             f"(reduced grid)")
            else:
                fatal.append(f"tuning key {key_slug(k)} missing from "
                             f"current table")
            continue
        bw, cw = base[k]["winner"], ce["winner"]
        if bw != cw:
            fatal.append(f"winner changed at {key_slug(k)}: baseline "
                         f"{bw!r} -> current {cw!r}")
    for k in sorted(cur, key=key_order):
        if k not in base:
            notes.append(f"tuning key {key_slug(k)} is new in current table")
    return fatal, notes
