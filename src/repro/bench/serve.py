"""``serve_load`` scenario family: open-loop serving traces -> percentiles.

TaPS-style declarative workload family for the serving engine: a
``ServeLoadSpec`` names a deterministic synthetic arrival trace (seeded
inter-arrival times + prompt/output-length distributions) and an engine
configuration (decode mode, slot pool, chunk size); running it yields
TTFT / TPOT / end-to-end latency percentiles, decode throughput and
goodput — the serve analogue of the METG sweep, reported as percentile
curves per the granularity-characterization methodology rather than
single means.

Two execution paths, selected by the context timer:

* ``wallclock`` — drive the REAL ``ServeEngine`` (reduced model) open
  loop: requests are submitted when the wall clock passes their arrival
  time, latencies come from the engine's per-request marks.
* ``synthetic`` — a deterministic discrete-event simulator that replays
  the engine's exact scheduling (slot-granular admission between decode
  ticks, per-slot budgets, chunked ``while_loop`` semantics) in virtual
  time under a ``ServeCostParams`` cost model.  Zero noise, so the
  committed ``BENCH_serve_load.*.json`` baselines sit under the CI
  ``--baseline`` gate, and the host-sync arithmetic is exact: host mode
  pays ``launch + step + sync`` per TOKEN, chunked mode pays
  ``launch + steps*step + sync`` per CHUNK — the O(tokens) ->
  O(tokens/chunk) sync reduction the tentpole claims, in closed form.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

DEFAULT_MODEL = "qwen1.5-0.5b"


@dataclasses.dataclass(frozen=True)
class ServeCostParams:
    """Virtual-time costs for the deterministic serve simulator.

    Magnitudes follow the paper's §IV-B overhead anatomy: dispatch and
    device->host sync are tens of microseconds — the same order as (or
    larger than) a decode step's useful work on a small model, which is
    exactly why per-token syncing caps decode throughput.
    """

    prefill_launch_s: float = 50e-6   # dispatch overhead per prefill launch
    prefill_token_s: float = 2e-6     # per prompt token
    decode_launch_s: float = 30e-6    # dispatch overhead per decode launch
    decode_step_s: float = 20e-6      # per decode step (whole batch)
    sync_s: float = 40e-6             # per device->host round-trip

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ServeLoadSpec:
    """One serve_load cell: a seeded open-loop trace x engine config."""

    name: str
    mode: str = "chunked"            # "chunked" | "host"
    rate_rps: float = 50.0           # mean arrival rate (open loop)
    num_requests: int = 64
    batch_slots: int = 4
    chunk_size: int = 8
    max_len: int = 96
    prompt_len: tuple = (4, 12)      # uniform inclusive range
    out_tokens: tuple = (4, 24)      # uniform inclusive range
    seed: int = 0
    model: str = DEFAULT_MODEL       # wallclock mode only (reduced config)
    smoke: bool = False

    def __post_init__(self):
        if not self.name:
            raise ValueError("scenario name must be non-empty")
        if self.mode not in ("chunked", "host"):
            raise ValueError(f"unknown serve mode {self.mode!r}")
        if self.rate_rps <= 0:
            raise ValueError("rate_rps must be > 0")
        if self.num_requests < 1:
            raise ValueError("num_requests must be >= 1")
        for lo, hi, what in (self.prompt_len + ("prompt_len",),
                             self.out_tokens + ("out_tokens",)):
            if not (1 <= lo <= hi):
                raise ValueError(f"{what} range must satisfy 1 <= lo <= hi")
        if self.prompt_len[1] + self.out_tokens[1] > self.max_len:
            raise ValueError(
                f"prompt_len[1] + out_tokens[1] = "
                f"{self.prompt_len[1] + self.out_tokens[1]} exceeds "
                f"max_len={self.max_len}")

    @property
    def slug(self) -> str:
        """Filesystem-safe scenario key: BENCH_<slug>.json."""
        return re.sub(r"[^A-Za-z0-9_.-]+", "-", self.name)

    def resolved(self, smoke: Optional[bool] = None) -> "ServeLoadSpec":
        """The spec a run actually measures (smoke ceiling applied)."""
        smoke = self.smoke if smoke is None else smoke
        if not smoke:
            return dataclasses.replace(self, smoke=False)
        return dataclasses.replace(
            self, smoke=True, num_requests=min(self.num_requests, 16))


@dataclasses.dataclass(frozen=True)
class TracedRequest:
    rid: int
    arrival_s: float
    prompt_len: int
    out_tokens: int   # total tokens to generate (prefill token included)


def synth_trace(spec: ServeLoadSpec) -> List[TracedRequest]:
    """The deterministic open-loop trace for ``spec`` (seeded PRNG)."""
    rng = np.random.default_rng(spec.seed)
    out, t = [], 0.0
    for rid in range(spec.num_requests):
        t += float(rng.exponential(1.0 / spec.rate_rps))
        out.append(TracedRequest(
            rid=rid, arrival_s=t,
            prompt_len=int(rng.integers(spec.prompt_len[0],
                                        spec.prompt_len[1] + 1)),
            out_tokens=int(rng.integers(spec.out_tokens[0],
                                        spec.out_tokens[1] + 1))))
    return out


@dataclasses.dataclass
class ServeLoadResult:
    spec: ServeLoadSpec
    timer: str                 # "wallclock" | "synthetic"
    timer_config: Dict
    metrics: Dict


def _pcts(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean())}


def _metrics(trace, t_first, t_done, makespan_s, stats) -> Dict:
    ttft = [t_first[r.rid] - r.arrival_s for r in trace]
    latency = [t_done[r.rid] - r.arrival_s for r in trace]
    tpot = [(t_done[r.rid] - t_first[r.rid]) / (r.out_tokens - 1)
            for r in trace if r.out_tokens > 1]
    toks = stats["tokens_generated"]
    mk = max(makespan_s, 1e-12)
    return {
        "ttft_s": _pcts(ttft),
        "tpot_s": _pcts(tpot),
        "latency_s": _pcts(latency),
        "throughput_tok_s": toks / mk,
        "goodput_rps": len(trace) / mk,
        "makespan_s": makespan_s,
        "host_syncs": stats["host_syncs"],
        "host_syncs_per_token": stats["host_syncs"] / max(toks, 1),
        "decode_steps": stats["decode_steps"],
        "chunk_launches": stats["chunk_launches"],
        "prefills": stats["prefills"],
        "tokens_generated": toks,
        "completed": len(trace),
    }


# ----------------------------------------------------- deterministic model
def simulate_serve_load(spec: ServeLoadSpec,
                        cost: Optional[ServeCostParams] = None,
                        ) -> ServeLoadResult:
    """Replay the engine's scheduling in virtual time under ``cost``.

    Mirrors ``ServeEngine.step`` exactly: each tick admits arrived
    requests into free slots (one sequential B=1 prefill each, one sync
    for its first token), then advances one decode launch — ``chunk_size``
    steps in chunked mode (the while_loop stops early once every slot's
    budget is spent, so steps = min(chunk, max remaining)), one step in
    host mode — with one sync per launch.  Tokens materialize on the host
    at the launch's sync, which is when completions are observed.
    """
    spec = spec.resolved()
    cost = cost or ServeCostParams()
    trace = synth_trace(spec)
    pending = list(trace)
    slots: List[Optional[List]] = [None] * spec.batch_slots  # [req, rem]
    t = 0.0
    t_first: Dict[int, float] = {}
    t_done: Dict[int, float] = {}
    stats = {"prefills": 0, "decode_steps": 0, "chunk_launches": 0,
             "host_syncs": 0, "tokens_generated": 0}
    while pending or any(s is not None for s in slots):
        for i in range(spec.batch_slots):  # slot-granular admission
            if slots[i] is not None or not pending:
                continue
            if pending[0].arrival_s > t:
                break
            r = pending.pop(0)
            t += (cost.prefill_launch_s
                  + r.prompt_len * cost.prefill_token_s + cost.sync_s)
            stats["prefills"] += 1
            stats["host_syncs"] += 1
            stats["tokens_generated"] += 1
            t_first[r.rid] = t
            if r.out_tokens <= 1:
                t_done[r.rid] = t
            else:
                slots[i] = [r, r.out_tokens - 1]
        occupied = [i for i, s in enumerate(slots) if s is not None]
        if not occupied:
            if pending:
                t = max(t, pending[0].arrival_s)
            continue
        if spec.mode == "chunked":
            steps = min(spec.chunk_size, max(slots[i][1] for i in occupied))
            stats["chunk_launches"] += 1
        else:
            steps = 1
        t += cost.decode_launch_s + steps * cost.decode_step_s + cost.sync_s
        stats["decode_steps"] += steps
        stats["host_syncs"] += 1
        for i in occupied:
            r, rem = slots[i]
            emitted = min(rem, steps)
            stats["tokens_generated"] += emitted
            if rem - emitted == 0:
                t_done[r.rid] = t
                slots[i] = None
            else:
                slots[i][1] = rem - emitted
    return ServeLoadResult(
        spec=spec, timer="synthetic", timer_config=cost.as_dict(),
        metrics=_metrics(trace, t_first, t_done, t, stats))


# ------------------------------------------------------- real-engine path
def run_engine_load(spec: ServeLoadSpec, cfg=None, params=None,
                    ) -> ServeLoadResult:
    """Drive a real ``ServeEngine`` open loop and measure wall-clock
    latencies.  ``cfg``/``params`` default to the spec's model reduced —
    pass both to reuse compiled programs across cells."""
    import time

    import jax

    from ..serve.engine import ServeEngine

    spec = spec.resolved()
    if cfg is None:
        from ..configs import get_config, reduced
        from ..models import model as M
        from ..models.layers import split_leaves

        cfg = reduced(get_config(spec.model))
        params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
    engine = ServeEngine(cfg, params, batch_slots=spec.batch_slots,
                         max_len=spec.max_len, chunk_size=spec.chunk_size,
                         decode_mode=spec.mode)
    trace = synth_trace(spec)
    prng = np.random.default_rng(spec.seed + 1)
    prompts = {r.rid: prng.integers(1, cfg.vocab_size,
                                    size=r.prompt_len).astype(np.int32)
               for r in trace}
    done: Dict[int, object] = {}
    rid_of: Dict[int, int] = {}
    nsub = 0
    t0 = time.perf_counter()
    while nsub < len(trace) or engine.has_work:
        now = time.perf_counter() - t0
        while nsub < len(trace) and trace[nsub].arrival_s <= now:
            r = trace[nsub]
            rid_of[engine.submit(prompts[r.rid],
                                 max_new_tokens=r.out_tokens)] = r.rid
            nsub += 1
        if not engine.has_work:
            time.sleep(min(max(trace[nsub].arrival_s - now, 0.0), 1e-3))
            continue
        for req in engine.step():
            done[rid_of[req.rid]] = req
    t_first = {rid: req.t_first - t0 for rid, req in done.items()}
    t_done = {rid: req.t_done - t0 for rid, req in done.items()}
    return ServeLoadResult(
        spec=spec, timer="wallclock", timer_config={},
        metrics=_metrics(trace, t_first, t_done,
                         max(t_done.values()), engine.stats))


def run_serve_load(spec: ServeLoadSpec, timer=None,
                   cost: Optional[ServeCostParams] = None) -> ServeLoadResult:
    """Run one serve_load cell: real engine (timer None / wallclock) or
    the deterministic simulator (the synthetic fake clock)."""
    if timer is None or getattr(timer, "name", None) == "wallclock":
        return run_engine_load(spec)
    if getattr(timer, "name", None) == "synthetic":
        return simulate_serve_load(spec, cost=cost)
    raise ValueError(
        f"serve_load supports the wallclock and synthetic timers, "
        f"got {getattr(timer, 'name', timer)!r}")


def serve_artifact(result: ServeLoadResult) -> Dict:
    """The JSON-serializable ``kind="serve_load"`` artifact document
    (deep-copied: mutating it never reaches back into the result)."""
    import copy

    from .artifact import SCHEMA_VERSION

    spec = result.spec
    return {
        "schema": SCHEMA_VERSION,
        "kind": "serve_load",
        "scenario": {
            "name": spec.name,
            "mode": spec.mode,
            "rate_rps": float(spec.rate_rps),
            "num_requests": spec.num_requests,
            "batch_slots": spec.batch_slots,
            "chunk_size": spec.chunk_size,
            "max_len": spec.max_len,
            "prompt_len_lo": spec.prompt_len[0],
            "prompt_len_hi": spec.prompt_len[1],
            "out_tokens_lo": spec.out_tokens[0],
            "out_tokens_hi": spec.out_tokens[1],
            "seed": spec.seed,
            "model": spec.model,
            "smoke": spec.smoke,
        },
        "timer": result.timer,
        "timer_config": dict(result.timer_config),
        "metrics": copy.deepcopy(result.metrics),
    }


def write_serve_json(result: ServeLoadResult, outdir: str) -> str:
    """Write ``BENCH_<scenario>.json`` (validated); returns the path."""
    from .artifact import validate_artifact, write_artifact_doc

    return write_artifact_doc(validate_artifact(serve_artifact(result)),
                              result.spec.slug, outdir)
