"""Machine-readable benchmark artifacts: ``BENCH_<scenario>.json``.

One JSON file per scenario, schema-versioned, carrying the resolved spec,
the timer used, the full efficiency curve and the METG — everything a later
PR (or the CI artifact collector) needs to track the perf trajectory
without re-parsing CSV stdout.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict

from .sweep import ScenarioResult

SCHEMA_VERSION = 1


def _canonical_backend(spec: str) -> str:
    """Artifacts record the canonical backend spec (options sorted by
    key) so artifact identity never depends on how a scenario author
    ordered the options; unparseable specs record raw."""
    from ..backends.base import canonical_backend_spec

    try:
        return canonical_backend_spec(spec)
    except ValueError:
        return spec

# field name -> required type(s); None-able fields listed separately
_POINT_FIELDS = {
    "iterations": int,
    "num_tasks": int,
    "wall_time_s": (int, float),
    "useful_work": (int, float),
    "granularity_s": (int, float),
    "rate": (int, float),
    "efficiency": (int, float),
}
_SCENARIO_FIELDS = {
    "name": str,
    "backend": str,
    "pattern": str,
    "kernel": str,
    "width": int,
    "height": int,
    "output_bytes": int,
    "imbalance": (int, float),
    "ngraphs": int,
    "cores": int,
    "graph_kw": dict,
    "sweep": dict,
}

# --- kind="serve_load" (repro.bench.serve): open-loop serving traces ---
_SERVE_SCENARIO_FIELDS = {
    "name": str,
    "mode": str,
    "rate_rps": (int, float),
    "num_requests": int,
    "batch_slots": int,
    "chunk_size": int,
    "max_len": int,
    "prompt_len_lo": int,
    "prompt_len_hi": int,
    "out_tokens_lo": int,
    "out_tokens_hi": int,
    "seed": int,
    "model": str,
}
_SERVE_PCT_KEYS = ("p50", "p95", "p99", "mean")
_SERVE_PCT_METRICS = ("ttft_s", "tpot_s", "latency_s")

# --- kind="metg_scaling" (repro.bench.scaling): weak-scaling rank sweep ---
_SCALING_SCENARIO_FIELDS = {
    "name": str,
    "backend": str,
    "pattern": str,
    "kernel": str,
    "width_per_rank": int,
    "height": int,
    "output_bytes": int,
    "ranks": list,
    "sweep": dict,
}
_SCALING_CELL_FIELDS = {
    "ranks": int,
    "width": int,
    "devices": int,
    "elapsed_s": (int, float),
    "granularity_s": (int, float),
    "weak_efficiency": (int, float),
}
_SCALING_POINT_FIELDS = {
    "iterations": int,
    "num_tasks": int,
    "wall_time_s": (int, float),
    "granularity_s": (int, float),
    "efficiency": (int, float),
    "weak_efficiency": (int, float),
}
_SERVE_SCALAR_METRICS = {
    "throughput_tok_s": (int, float),
    "goodput_rps": (int, float),
    "makespan_s": (int, float),
    "host_syncs_per_token": (int, float),
    "host_syncs": int,
    "decode_steps": int,
    "chunk_launches": int,
    "prefills": int,
    "tokens_generated": int,
    "completed": int,
}


def bench_artifact(result: ScenarioResult) -> Dict:
    """The JSON-serializable artifact for one scenario result."""
    spec = result.spec
    sweep = dataclasses.asdict(spec.sweep)
    sweep["schedule"] = (list(spec.sweep.schedule)
                        if spec.sweep.schedule is not None else None)
    return {
        "schema": SCHEMA_VERSION,
        "kind": "metg_sweep",
        "scenario": {
            "name": spec.name,
            "backend": _canonical_backend(spec.backend),
            "pattern": spec.pattern,
            "kernel": spec.kernel,
            "width": spec.width,
            "height": spec.height,
            "output_bytes": spec.output_bytes,
            "imbalance": spec.imbalance,
            "ngraphs": spec.ngraphs,
            "cores": spec.cores,
            "graph_kw": dict(spec.graph_kw),
            "sweep": sweep,
        },
        "timer": result.timer,
        # authoritative measurement parameters (a timer override supersedes
        # spec.sweep's warmup/repeats/percentile; this records what ran)
        "timer_config": dict(result.timer_config),
        "threshold": result.metg.threshold,
        "peak_rate": result.metg.peak_rate,
        "metg_s": result.metg.metg,
        "points": [
            {
                "iterations": p.iterations,
                "num_tasks": p.num_tasks,
                "wall_time_s": p.wall_time,
                "useful_work": p.useful_work,
                "granularity_s": p.granularity,
                "rate": p.rate,
                "efficiency": p.efficiency,
            }
            for p in sorted(result.points, key=lambda p: -p.iterations)
        ],
    }


def _typed(v, t) -> bool:
    """isinstance with bools rejected for numeric fields (bool <: int)
    and NaN/inf rejected for floats — a corrupt study artifact (e.g. a
    degenerate-metric division leaking through) fails the schema check
    here, not the CI gate arithmetic downstream."""
    if isinstance(v, bool):
        return False
    if isinstance(v, float) and not math.isfinite(v):
        return False
    return isinstance(v, t)


def validate_artifact(doc: Dict) -> Dict:
    """Schema check (raises ValueError); returns ``doc`` for chaining."""

    def need(cond, msg):
        if not cond:
            raise ValueError(f"invalid bench artifact: {msg}")

    need(isinstance(doc, dict), "not an object")
    need(doc.get("schema") == SCHEMA_VERSION,
         f"schema must be {SCHEMA_VERSION}, got {doc.get('schema')!r}")
    need(doc.get("kind") in ("metg_sweep", "serve_load", "metg_scaling"),
         f"unknown kind {doc.get('kind')!r}")
    # any non-empty name is valid: Timer is an open protocol (custom
    # timers must not be rejected at the artifact layer)
    need(isinstance(doc.get("timer"), str) and doc.get("timer"),
         f"timer must be a non-empty string, got {doc.get('timer')!r}")
    need(isinstance(doc.get("timer_config"), dict), "timer_config")
    if doc["kind"] == "serve_load":
        return _validate_serve_load(doc, need)
    if doc["kind"] == "metg_scaling":
        return _validate_metg_scaling(doc, need)
    need(_typed(doc.get("threshold"), (int, float)), "threshold")
    need(_typed(doc.get("peak_rate"), (int, float)), "peak_rate")
    need("metg_s" in doc, "metg_s missing (null means no crossing)")
    need(doc["metg_s"] is None or _typed(doc["metg_s"], (int, float)),
         "metg_s")
    sc = doc.get("scenario")
    need(isinstance(sc, dict), "scenario missing")
    for k, t in _SCENARIO_FIELDS.items():
        if t is str:  # identity fields must be non-empty (mirrors the spec)
            need(isinstance(sc.get(k), str) and sc.get(k),
                 f"scenario.{k} must be a non-empty string")
        elif t is dict:
            need(isinstance(sc.get(k), t), f"scenario.{k} must be {t}")
        else:
            need(_typed(sc.get(k), t), f"scenario.{k} must be {t}")
    pts = doc.get("points")
    need(isinstance(pts, list) and pts, "points must be a non-empty list")
    for n, p in enumerate(pts):
        need(isinstance(p, dict), f"points[{n}] not an object")
        for k, t in _POINT_FIELDS.items():
            need(_typed(p.get(k), t), f"points[{n}].{k} must be {t}")
    return doc


def _validate_serve_load(doc: Dict, need) -> Dict:
    """Schema for ``kind="serve_load"`` (see ``repro.bench.serve``)."""
    sc = doc.get("scenario")
    need(isinstance(sc, dict), "scenario missing")
    for k, t in _SERVE_SCENARIO_FIELDS.items():
        if t is str:
            need(isinstance(sc.get(k), str) and sc.get(k),
                 f"scenario.{k} must be a non-empty string")
        else:
            need(_typed(sc.get(k), t), f"scenario.{k} must be {t}")
    need(sc["mode"] in ("chunked", "host"),
         f"scenario.mode must be chunked|host, got {sc['mode']!r}")
    need(isinstance(sc.get("smoke"), bool), "scenario.smoke must be a bool")
    m = doc.get("metrics")
    need(isinstance(m, dict), "metrics missing")
    for k in _SERVE_PCT_METRICS:
        p = m.get(k)
        need(isinstance(p, dict), f"metrics.{k} must be an object")
        for q in _SERVE_PCT_KEYS:
            need(_typed(p.get(q), (int, float)),
                 f"metrics.{k}.{q} must be a number")
    for k, t in _SERVE_SCALAR_METRICS.items():
        need(_typed(m.get(k), t), f"metrics.{k} must be {t}")
    return doc


def _validate_metg_scaling(doc: Dict, need) -> Dict:
    """Schema for ``kind="metg_scaling"`` (see ``repro.bench.scaling``)."""
    sc = doc.get("scenario")
    need(isinstance(sc, dict), "scenario missing")
    for k, t in _SCALING_SCENARIO_FIELDS.items():
        if t is str:
            need(isinstance(sc.get(k), str) and sc.get(k),
                 f"scenario.{k} must be a non-empty string")
        elif t in (list, dict):
            need(isinstance(sc.get(k), t), f"scenario.{k} must be {t}")
        else:
            need(_typed(sc.get(k), t), f"scenario.{k} must be {t}")
    ranks = sc["ranks"]
    need(ranks and all(_typed(n, int) and n >= 1 for n in ranks),
         "scenario.ranks must be a non-empty list of rank counts >= 1")
    need(list(ranks) == sorted(set(ranks)),
         f"scenario.ranks must be strictly ascending, got {ranks}")
    need(ranks[0] == 1,
         "scenario.ranks must start at 1 (the weak-scaling reference)")
    cells = doc.get("cells")
    need(isinstance(cells, list) and cells, "cells must be a non-empty list")
    need([c.get("ranks") for c in cells if isinstance(c, dict)] == list(ranks),
         "cells must cover scenario.ranks exactly, in order")
    for n, c in enumerate(cells):
        need(isinstance(c, dict), f"cells[{n}] not an object")
        for k, t in _SCALING_CELL_FIELDS.items():
            need(_typed(c.get(k), t), f"cells[{n}].{k} must be {t}")
        need(c["width"] == sc["width_per_rank"] * c["ranks"],
             f"cells[{n}].width must be width_per_rank * ranks "
             f"(fixed work per rank), got {c['width']}")
        pts = c.get("points")
        need(isinstance(pts, list) and pts,
             f"cells[{n}].points must be a non-empty list")
        for m, p in enumerate(pts):
            need(isinstance(p, dict), f"cells[{n}].points[{m}] not an object")
            for k, t in _SCALING_POINT_FIELDS.items():
                need(_typed(p.get(k), t),
                     f"cells[{n}].points[{m}].{k} must be {t}")
    return doc


def artifact_path(slug: str, outdir: str) -> str:
    """Where ``write_bench_json`` will put a scenario's artifact."""
    return os.path.join(outdir, f"BENCH_{slug}.json")


def write_artifact_doc(doc: Dict, slug: str, outdir: str) -> str:
    """Write a validated artifact document atomically; returns the path."""
    os.makedirs(outdir, exist_ok=True)
    path = artifact_path(slug, outdir)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


def write_bench_json(result: ScenarioResult, outdir: str) -> str:
    """Write ``BENCH_<scenario>.json`` (validated); returns the path."""
    doc = validate_artifact(bench_artifact(result))
    return write_artifact_doc(doc, result.spec.slug, outdir)


def read_bench_json(path: str) -> Dict:
    """Read + schema-check one artifact.

    Truncated or garbage files raise ``ValueError`` naming the path (not a
    bare ``JSONDecodeError``), so corrupt artifacts fail the same way as
    schema violations — callers catch one exception type.
    """
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"invalid bench artifact: {path} is not valid JSON "
                f"(truncated or garbage: {e})") from e
    return validate_artifact(doc)
