"""Scenario execution: spec -> sweep points -> efficiency curve -> METG.

``run_scenario`` is the one entry point the benchmark scripts (and tests)
call: it resolves the spec (smoke ceilings), walks the iteration schedule,
asks the ``Timer`` for the wall time of each point's concurrent graph list,
and reduces the points to a ``METGResult``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .metg import METGResult, SweepPoint, compute_metg, sweep_point
from .scenario import ScenarioSpec
from .timers import Timer, WallClockTimer, timer_config


@dataclass
class ScenarioResult:
    """One scenario's measured sweep, ready for the artifact writer."""

    spec: ScenarioSpec      # the *resolved* spec that was measured
    timer: str              # timer name ("wallclock" | "synthetic" | ...)
    metg: METGResult
    # the timer's actual parameters — authoritative over spec.sweep's
    # warmup/repeats/percentile when a timer override was supplied
    timer_config: Dict[str, object] = field(default_factory=dict)

    @property
    def points(self) -> List[SweepPoint]:
        return self.metg.points

    @property
    def peak_rate(self) -> float:
        return self.metg.peak_rate

    @property
    def metg_s(self) -> Optional[float]:
        return self.metg.metg


def run_scenario(
    spec: ScenarioSpec,
    timer: Optional[Timer] = None,
    peak_rate: Optional[float] = None,
) -> ScenarioResult:
    """Measure one scenario under ``timer`` (default: wall clock).

    ``peak_rate`` pins the 100 %-efficiency baseline externally (e.g. the
    balanced peak when measuring an imbalance penalty); by default the
    sweep self-normalizes against its own best rate.
    """
    spec = spec.resolved()
    if timer is None:
        timer = WallClockTimer(warmup=spec.sweep.warmup,
                               repeats=spec.sweep.repeats,
                               percentile=spec.sweep.percentile)
    points: List[SweepPoint] = []
    for iters in spec.sweep.iteration_schedule():
        graphs = spec.graphs(iters)
        wall = timer.measure(spec.backend, graphs)
        points.append(sweep_point(graphs, iters, wall, cores=spec.cores))
    result = compute_metg(points, threshold=spec.sweep.threshold,
                          peak_rate=peak_rate)
    return ScenarioResult(spec=spec, timer=timer.name, metg=result,
                          timer_config=timer_config(timer))
