"""Diff ``BENCH_<scenario>.json`` artifacts: the perf-regression gate.

``compare_artifacts`` diffs one scenario's current artifact against a
baseline with a *relative* threshold: the METG and each sweep point's
recorded wall time (each point's value is already the repeats-reduced
statistic — best-of-N or the configured percentile — so the per-point
comparison is a median-style comparison, not a single noisy sample).
Only slowdowns beyond the threshold regress; speedups are reported but
never fail.

``compare_dirs`` matches artifacts by filename across two directories —
every baseline scenario must still exist and hold its numbers; scenarios
that are *new* in the current run pass (they have no baseline yet) but
are named in the summary, so a typo'd rename shows up as vanished+new
instead of silently dropping its baseline coverage.

``benchmarks/run.py --baseline <dir>`` runs the comparison after a sweep
and exits nonzero on any regression; CI runs it with the deterministic
``--timer synthetic`` fake clock against the committed
``benchmarks/baselines/`` snapshot, so the gate is noise-free: it trips
on real changes to graph structure, task counts, or the sweep itself,
not on runner jitter.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .artifact import read_bench_json

DEFAULT_THRESHOLD = 0.25  # relative slowdown tolerated before failing


def _canonical_backend(spec: str) -> str:
    """Backend identity for the diff: canonical spec when parseable.

    Unparseable strings compare raw — a malformed baseline should fail
    as a visible identity mismatch, not crash the gate.
    """
    from ..backends.base import canonical_backend_spec

    try:
        return canonical_backend_spec(spec)
    except ValueError:
        return spec


class ZeroBaselineError(ValueError):
    """A baseline point of 0.0 against a nonzero current value.

    There is no finite relative delta to compare against the threshold —
    comparing ``inf`` (the old behavior) silently turned the point into
    an unconditional failure with a non-finite number in the report.  A
    measured point recorded as 0.0 means the artifacts disagree about
    what was measured (an identity mismatch), consistent with the
    finiteness guards in ``validate_artifact``; both-zero compares equal.
    """


def _rel_delta(baseline: float, current: float) -> float:
    if baseline == 0:
        if current == 0:
            return 0.0
        raise ZeroBaselineError(
            f"baseline is 0.0 but current is {current:.4g} — no finite "
            f"relative delta (zero-baseline points are an identity "
            f"mismatch, not a perf signal)")
    return (current - baseline) / baseline


@dataclass(frozen=True)
class PointDelta:
    """One matched sweep point (same iteration count) across the diff."""

    iterations: int
    baseline_s: float
    current_s: float
    rel_delta: float
    regressed: bool


@dataclass
class ComparisonResult:
    """One scenario's diff: METG movement + per-point wall-time deltas."""

    scenario: str
    metg_baseline: Optional[float] = None
    metg_current: Optional[float] = None
    metg_rel_delta: Optional[float] = None
    points: List[PointDelta] = field(default_factory=list)
    regressions: List[str] = field(default_factory=list)
    note: str = ""  # headline movement for non-METG kinds (serve_load)

    @property
    def ok(self) -> bool:
        return not self.regressions

    def summary(self) -> str:
        if self.ok:
            if self.note:
                return f"{self.scenario}: ok ({self.note})"
            d = self.metg_rel_delta
            moved = f"metg{d:+.1%}" if d is not None else "no-metg"
            return f"{self.scenario}: ok ({moved})"
        return f"{self.scenario}: REGRESSION " + "; ".join(self.regressions)


# metrics where LOWER is better: any increase beyond threshold regresses
_SERVE_LATENCY_METRICS = ("ttft_s", "tpot_s", "latency_s")
# metrics where HIGHER is better: any drop beyond threshold regresses
_SERVE_RATE_METRICS = ("throughput_tok_s", "goodput_rps")
_SERVE_IDENTITY = ("name", "mode", "rate_rps", "num_requests", "batch_slots",
                   "chunk_size", "seed", "model")


def _compare_serve(baseline: Dict, current: Dict, rel_threshold: float,
                   res: ComparisonResult) -> ComparisonResult:
    """serve_load diff: latency percentiles up or rates down = regression."""
    bm, cm = baseline["metrics"], current["metrics"]
    for k in _SERVE_RATE_METRICS:
        try:
            rel = _rel_delta(bm[k], cm[k])  # negative = slower
        except ZeroBaselineError as e:
            res.regressions.append(f"{k}: {e}")
            continue
        if -rel > rel_threshold:
            res.regressions.append(
                f"{k} {bm[k]:.4g} -> {cm[k]:.4g} "
                f"({rel:+.1%} < -{rel_threshold:.0%})")
    for k in _SERVE_LATENCY_METRICS:
        for q in ("p50", "p95", "p99"):
            try:
                rel = _rel_delta(bm[k][q], cm[k][q])
            except ZeroBaselineError as e:
                res.regressions.append(f"{k}.{q}: {e}")
                continue
            if rel > rel_threshold:
                res.regressions.append(
                    f"{k}.{q} {bm[k][q]:.3e}s -> {cm[k][q]:.3e}s "
                    f"(+{rel:.1%} > {rel_threshold:.0%})")
    try:
        thr = _rel_delta(bm["throughput_tok_s"], cm["throughput_tok_s"])
        res.note = f"thr{thr:+.1%}"
    except ZeroBaselineError:
        res.note = ""  # already a regression via the rate loop above
    return res


# metg_scaling identity: the rank sweep's shape axes (a changed rank
# list or per-rank width is a different experiment, not a perf delta)
_SCALING_IDENTITY = ("name", "backend", "pattern", "kernel",
                     "width_per_rank", "height", "output_bytes", "ranks")


def _compare_scaling(baseline: Dict, current: Dict, rel_threshold: float,
                     res: ComparisonResult) -> ComparisonResult:
    """metg_scaling diff: per-rank elapsed up or weak-scaling efficiency
    down beyond threshold = regression; a vanished rank cell regresses."""
    cur_cells = {c["ranks"]: c for c in current["cells"]}
    for bc in baseline["cells"]:
        n = bc["ranks"]
        cc = cur_cells.get(n)
        if cc is None:
            res.regressions.append(f"rank cell ranks={n} missing")
            continue
        try:
            rel = _rel_delta(bc["elapsed_s"], cc["elapsed_s"])
        except ZeroBaselineError as e:
            res.regressions.append(f"ranks={n} elapsed: {e}")
            continue
        if rel > rel_threshold:
            res.regressions.append(
                f"ranks={n} elapsed {bc['elapsed_s']:.3e}s -> "
                f"{cc['elapsed_s']:.3e}s (+{rel:.1%} > {rel_threshold:.0%})")
        try:
            eff = _rel_delta(bc["weak_efficiency"], cc["weak_efficiency"])
        except ZeroBaselineError as e:
            res.regressions.append(f"ranks={n} weak_efficiency: {e}")
            continue
        if -eff > rel_threshold:
            res.regressions.append(
                f"ranks={n} weak_efficiency {bc['weak_efficiency']:.3f} -> "
                f"{cc['weak_efficiency']:.3f} "
                f"({eff:+.1%} < -{rel_threshold:.0%})")
        for bp in bc["points"]:
            it = bp["iterations"]
            cp = next((p for p in cc["points"]
                       if p["iterations"] == it), None)
            if cp is None:
                res.regressions.append(
                    f"ranks={n} sweep point iterations={it} missing")
                continue
            try:
                prel = _rel_delta(bp["wall_time_s"], cp["wall_time_s"])
            except ZeroBaselineError as e:
                res.regressions.append(f"ranks={n} iterations={it}: {e}")
                continue
            if prel > rel_threshold:
                res.regressions.append(
                    f"ranks={n} iterations={it}: {bp['wall_time_s']:.3e}s "
                    f"-> {cp['wall_time_s']:.3e}s "
                    f"(+{prel:.1%} > {rel_threshold:.0%})")
    top = max(c["ranks"] for c in baseline["cells"])
    cc = cur_cells.get(top)
    if cc is not None and res.ok:
        res.note = f"eff@r{top}={cc['weak_efficiency']:.3f}"
    return res


def compare_artifacts(baseline: Dict, current: Dict,
                      rel_threshold: float = DEFAULT_THRESHOLD,
                      ) -> ComparisonResult:
    """Diff two validated artifact documents for the same scenario."""
    if rel_threshold <= 0:
        raise ValueError(f"rel_threshold must be > 0, got {rel_threshold}")
    name = baseline["scenario"]["name"]
    res = ComparisonResult(scenario=name)
    bk = baseline.get("kind", "metg_sweep")
    ck = current.get("kind", "metg_sweep")
    if bk != ck:
        res.regressions.append(
            f"kind changed: baseline {bk!r} vs current {ck!r} "
            f"(artifacts are not comparable)")
        return res
    if bk == "metg_scaling":
        for key in _SCALING_IDENTITY:
            b, c = baseline["scenario"][key], current["scenario"][key]
            if key == "backend":
                b, c = _canonical_backend(b), _canonical_backend(c)
            if b != c:
                res.regressions.append(
                    f"scenario.{key} changed: baseline {b!r} vs current {c!r}")
        bt, ct = baseline["timer"], current["timer"]
        if bt != ct:
            res.regressions.append(
                f"timer changed: baseline {bt!r} vs current {ct!r} "
                f"(times are not comparable)")
        if res.regressions:
            return res
        return _compare_scaling(baseline, current, rel_threshold, res)
    if bk == "serve_load":
        for key in _SERVE_IDENTITY:
            b, c = baseline["scenario"][key], current["scenario"][key]
            if b != c:
                res.regressions.append(
                    f"scenario.{key} changed: baseline {b!r} vs current {c!r}")
        bt, ct = baseline["timer"], current["timer"]
        if bt != ct:
            res.regressions.append(
                f"timer changed: baseline {bt!r} vs current {ct!r} "
                f"(times are not comparable)")
        if res.regressions:
            return res
        return _compare_serve(baseline, current, rel_threshold, res)
    for key in ("name", "backend", "pattern", "kernel"):
        b, c = baseline["scenario"][key], current["scenario"][key]
        if key == "backend":
            # compare canonically: option order inside the spec string is
            # not identity ("x[a=1,b=2]" == "x[b=2,a=1]"), so an old
            # baseline written with reordered keys never reads as a
            # changed (or vanished) scenario
            b, c = _canonical_backend(b), _canonical_backend(c)
        if b != c:
            res.regressions.append(
                f"scenario.{key} changed: baseline {b!r} vs current {c!r}")
    # wall-clock seconds vs a fake-clock baseline (or vice versa) is a
    # meaningless diff, not a perf signal — refuse, don't gate
    bt, ct = baseline["timer"], current["timer"]
    if bt != ct:
        res.regressions.append(
            f"timer changed: baseline {bt!r} vs current {ct!r} "
            f"(times are not comparable)")
    if res.regressions:
        return res  # identity mismatch: the numbers are not comparable

    mb, mc = baseline["metg_s"], current["metg_s"]
    res.metg_baseline, res.metg_current = mb, mc
    if mb is not None and mc is not None:
        try:
            res.metg_rel_delta = _rel_delta(mb, mc)
        except ZeroBaselineError as e:
            res.regressions.append(f"METG: {e}")
        else:
            if res.metg_rel_delta > rel_threshold:
                res.regressions.append(
                    f"METG {mb:.3e}s -> {mc:.3e}s "
                    f"(+{res.metg_rel_delta:.1%} > {rel_threshold:.0%})")
    elif mb is not None and mc is None:
        res.regressions.append(
            f"METG no longer crosses the efficiency threshold "
            f"(baseline {mb:.3e}s)")
    # baseline None: the scenario never crossed before — any crossing now
    # is an improvement, nothing to gate on

    cur_points = {p["iterations"]: p for p in current["points"]}
    for bp in baseline["points"]:
        it = bp["iterations"]
        cp = cur_points.get(it)
        if cp is None:
            res.regressions.append(f"sweep point iterations={it} missing")
            continue
        try:
            rel = _rel_delta(bp["wall_time_s"], cp["wall_time_s"])
        except ZeroBaselineError as e:
            res.regressions.append(f"point iterations={it}: {e}")
            continue
        regressed = rel > rel_threshold
        res.points.append(PointDelta(
            iterations=it, baseline_s=bp["wall_time_s"],
            current_s=cp["wall_time_s"], rel_delta=rel, regressed=regressed))
        if regressed:
            res.regressions.append(
                f"point iterations={it}: {bp['wall_time_s']:.3e}s -> "
                f"{cp['wall_time_s']:.3e}s (+{rel:.1%} > {rel_threshold:.0%})")
    return res


def bench_json_names(dirpath: str) -> List[str]:
    """Sorted BENCH_*.json filenames under ``dirpath``."""
    return sorted(f for f in os.listdir(dirpath)
                  if f.startswith("BENCH_") and f.endswith(".json"))


def scenario_family(fname: str) -> str:
    """The scenario family of a ``BENCH_<scenario>.json`` filename — the
    slug segment before the first dot (``BENCH_metg.xla-scan.nearest.json``
    -> ``"metg"``).  Scenarios of one family come from one bench module,
    so a partial run (``--only``) covers whole families."""
    base = os.path.basename(fname)
    if base.startswith("BENCH_"):
        base = base[len("BENCH_"):]
    return base.split(".")[0]


def compare_dirs(baseline_dir: str, current_dir: str,
                 rel_threshold: float = DEFAULT_THRESHOLD,
                 families: Optional[set] = None,
                 ) -> List[ComparisonResult]:
    """Diff every baseline artifact against its current counterpart.

    A baseline artifact with no current counterpart is a regression (a
    measured scenario silently disappeared); current artifacts without a
    baseline are new scenarios — they pass, but are *reported* in the
    summary (``"new in current run"``), because a new-looking artifact is
    also what a typo'd scenario rename produces: the old name trips the
    vanished-scenario regression and the note names its replacement, so
    the rename is visible end to end.  With ``families``, baseline
    artifacts of other scenario families are skipped entirely — the
    partial-run (``--only``) case, where the rest of the baseline was
    never remeasured and "missing" means "not run", not "vanished".
    Vanished-scenario detection is preserved *within* the families that
    did run.
    """
    if not os.path.isdir(baseline_dir):
        raise ValueError(f"baseline directory {baseline_dir!r} not found")
    results: List[ComparisonResult] = []
    base_names = set(bench_json_names(baseline_dir))
    for fname in sorted(base_names):
        if families is not None and scenario_family(fname) not in families:
            continue
        base = read_bench_json(os.path.join(baseline_dir, fname))
        cur_path = os.path.join(current_dir, fname)
        if not os.path.exists(cur_path):
            res = ComparisonResult(scenario=base["scenario"]["name"])
            res.regressions.append(
                f"artifact {fname} missing from current run")
            results.append(res)
            continue
        results.append(compare_artifacts(base, read_bench_json(cur_path),
                                         rel_threshold=rel_threshold))
    if os.path.isdir(current_dir):
        for fname in bench_json_names(current_dir):
            if fname in base_names:
                continue
            if (families is not None
                    and scenario_family(fname) not in families):
                continue
            results.append(ComparisonResult(
                scenario=fname,
                note="new in current run; no baseline yet (commit a "
                     "snapshot to gate it)"))
    return results


def format_report(results: List[ComparisonResult]) -> str:
    lines = [r.summary() for r in results]
    bad = sum(0 if r.ok else 1 for r in results)
    lines.append(f"compared {len(results)} scenario(s): "
                 + ("all within threshold" if not bad
                    else f"{bad} regression(s)"))
    return "\n".join(lines)
