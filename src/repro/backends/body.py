"""Shared JAX task body used by every backend (the O(m+n) trick).

All backends execute the *same* width-vectorized task body; they differ only
in how timesteps are scheduled and how dependency payloads move.  This
mirrors the paper's core API: the task body and kernels are provided
centrally so that backend comparisons are apples-to-apples (paper §II).

Numerical contract (must match core.kernel_ref bitwise for elementwise
kernels): the kernel state is seeded with ``start + acc * 2**-46`` where
``acc < 2**20`` — this rounds to exactly ``start`` in float32 (the increment
is below half an ulp of every start value used) but blocks XLA constant
folding, so the kernel loop is always executed at run time.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import CHECKSUM_MOD, TaskGraph
from ..core.kernel_spec import KernelSpec
from ..kernels import bodies

_FOLD_BLOCK = bodies.FOLD_BLOCK  # see module docstring


def checksum_vec(t, cols):
    """uint32-wrapping checksum; matches TaskGraph.checksum exactly."""
    t = jnp.asarray(t, jnp.uint32)
    cols = jnp.asarray(cols, jnp.uint32)
    k1 = jnp.uint32(2654435761)
    k2 = jnp.uint32(40503)
    return ((t * k1 + cols * k2) % jnp.uint32(CHECKSUM_MOD)).astype(jnp.uint32)


def combine_acc(dep_matrix, prev_combined):
    """acc_i = sum_j M[i,j] * combined_j  (mod 2^20), exact uint32 math."""
    m = dep_matrix.astype(jnp.uint32)  # (W, W)
    acc = (m * prev_combined[None, :].astype(jnp.uint32)).sum(axis=1)
    return (acc % jnp.uint32(CHECKSUM_MOD)).astype(jnp.uint32)


def run_kernel_vec(kernel: KernelSpec, iters_per_col, acc, max_iters: int,
                   dynamic: bool = False):
    """Vectorized kernel over width; returns (W,) f32 results.

    Thin rank adapter over ``kernels.bodies.run_kernel_columns`` — the
    megakernel backend and the standalone Pallas kernels call the same
    step functions, so every execution layer shares one code path (the
    reshapes here are exact; results stay bitwise identical).
    """
    seed = acc.astype(jnp.float32) * jnp.float32(_FOLD_BLOCK)
    out = bodies.run_kernel_columns(kernel, iters_per_col[:, None],
                                    seed[:, None], max_iters,
                                    dynamic=dynamic)
    return out[:, 0]


def make_payload(t, cols, base, combined, result, payload_elems: int):
    """Assemble the (ncols, P) payload rows for global column ids ``cols``."""
    n = cols.shape[0]
    tt = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (n,))
    head = jnp.stack(
        [tt, cols.astype(jnp.float32), base.astype(jnp.float32),
         combined.astype(jnp.float32), result],
        axis=1,
    )
    if payload_elems > 5:
        ballast = jnp.broadcast_to(result[:, None], (n, payload_elems - 5))
        return jnp.concatenate([head, ballast], axis=1)
    return head


def timestep(graph: TaskGraph, t, prev_payload, dep_matrix, iters_per_col,
             cols=None, dynamic: bool = False):
    """Execute one timestep of ``graph``, vectorized over a column block.

    prev_payload: (W_ctx, P) f32 from t-1 — the *context* columns this block
                  can read (full width for single-device backends; local
                  block + halo/gathered columns for CSP shards).
    dep_matrix:   (n, W_ctx) uint8 — rows select deps within the context.
    iters_per_col:(n,) int32 — per-task durations (imbalance-aware).
    cols:         (n,) global column ids (defaults to arange(W_ctx)).
    Returns the new (n, P) payload block.
    """
    if cols is None:
        cols = jnp.arange(graph.width)
    prev_combined = prev_payload[:, 3].astype(jnp.uint32)
    acc = combine_acc(dep_matrix, prev_combined)
    base = checksum_vec(t, cols)
    combined = (base + acc) % jnp.uint32(CHECKSUM_MOD)
    result = run_kernel_vec(graph.kernel, iters_per_col, acc,
                            graph.kernel.iterations, dynamic=dynamic)
    return make_payload(t, cols, base, combined, result, graph.payload_elems)


def graph_static_inputs(graph: TaskGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side constants: dep matrices (H,W,W) u8 and iteration counts (H,W) i32."""
    mats = graph.dependence_matrices().astype(np.uint8)
    iters = np.array(
        [[graph.task_iterations(t, i) for i in range(graph.width)]
         for t in range(graph.height)],
        dtype=np.int32,
    )
    return mats, iters


def stackable(graphs: Sequence[TaskGraph]) -> bool:
    """Can these graphs share one vectorized program with a graph axis?

    The task body closes over shape (width/payload) and kernel spec; the
    dependence matrices and iteration counts are data.  So graphs stack iff
    those static parts agree — patterns may differ freely.
    """
    if len(graphs) < 2:
        return False
    g0 = graphs[0]
    return all(
        g.width == g0.width
        and g.height == g0.height
        and g.output_bytes == g0.output_bytes
        and g.kernel == g0.kernel
        for g in graphs[1:]
    )


def stacked_static_inputs(
    graphs: Sequence[TaskGraph],
) -> Tuple[np.ndarray, np.ndarray]:
    """Static inputs with a leading graph axis: (G,H,W,W) u8, (G,H,W) i32."""
    per_graph = [graph_static_inputs(g) for g in graphs]
    mats = np.stack([m for m, _ in per_graph])
    iters = np.stack([i for _, i in per_graph])
    return mats, iters
