"""Persistent Pallas megakernel backend: one launch per task-graph batch.

Every other backend pays XLA's per-op dispatch on each timestep (a scan
iteration, an unrolled op chain, a host call per task) — exactly the
runtime overhead the paper identifies as the METG floor (§V-C: ~100 µs
even for the best runtimes).  Follow-up Task Bench studies show the METG
curve is *dominated* by this term, so the only way to move the curve is
to remove dispatches, not tune them.

This backend removes them: the whole task graph — all timesteps ×
columns, dependencies included — lowers into a *single* Pallas kernel
launch.

* The grid is ``(graphs, timesteps)``; TPU grids execute sequentially,
  so the trailing dimension is the timestep loop *inside* the kernel.
* The output block is revisited on every timestep of a graph and acts as
  the loop-carried payload wave: timestep ``t`` reads the block (the
  ``t-1`` payloads), resolves dependencies, and overwrites it.
* Dependencies are realized through that block — in-kernel VMEM reads
  indexed by the graph's dense dependency table
  (``TaskGraph.dependency_table``) — instead of XLA dataflow edges.
* The task body is ``kernels.bodies.run_kernel_columns``, the same
  traced code path the jitted backends execute, so conformance stays
  bit-exact.

Dispatch count per execution: 1 (vs H scan steps or H·W host calls).
``tests/test_megakernel.py`` pins this structurally: the TPU lowering of
the fused program contains exactly one kernel launch
(``tpu_custom_call``) and no ``stablehlo.while``, while ``xla-scan``'s
contains a while loop and no kernel launch.

CPU CI runs the kernel in Pallas interpret mode (``interpret=None``
auto-detects the platform); on TPU hosts Mosaic compiles the same kernel
— all in-kernel arithmetic keeps to Mosaic-legal forms (column-vector
shapes, int32 checksum math with the uint32 wrap-around base checksums
precomputed host-side via ``TaskGraph.checksum_table``; see
``kernels/bodies.py``).  The memory / compute_mxu task kernels are
validated in interpret mode only.

``comm="onesided"`` adds the distributed form of the same idea: one
*persistent, communicating* kernel per rank.  Columns are blocked over
the device mesh with the ``CommPlan`` one-sided layout
(``dist.collectives``, ``comm="onesided"``), and each rank's single
``pallas_call`` (grid over timesteps) pushes its dependency rows
straight into the consumers' receive buffers with
``pltpu.make_async_remote_copy`` — the NVSHMEM put — and consumes its
own inbox after a DMA-semaphore wait, the ``putmem_signal`` /
``signal_wait_until`` pair.  No XLA collective appears anywhere in the
lowering (``tests/test_megakernel.py`` pins that structurally): the
rendezvous is gone, which is how modern runtimes reach µs-scale task
granularity across ranks.  Every rank issues every put unconditionally
(ring offsets cover all live pairs; dead pairs deliver rows no
dependency-table entry references), keeping the DMA program
SPMD-uniform — the structure both real RDMA hardware and the interpret
emulation require.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..core.graph import CHECKSUM_MOD, TaskGraph
from ..core.kernel_ref import mxu_weight
from ..core.kernel_spec import MXU_DIM, KernelSpec
from ..dist import collectives as CC
from ..kernels import bodies
from . import body
from .base import StackedProgramBackend, register_backend


def _fused_kernel(idx_ref, mask_ref, iters_ref, base_ref, *rest,
                  kernel: KernelSpec, height: int, max_iters: int):
    """One grid step = one timestep of one graph, all columns.

    Refs (full-array blocks; G graphs share the leading table axis):
      idx/mask:   (G*H, W, R) int32 — dependency table rows
      iters:      (G*H, W, 1) int32 — per-task durations (imbalance)
      base:       (G*H, W, 1) int32 — precomputed base checksums
      [w]:        (MXU_DIM, MXU_DIM) f32 — only for the mxu kernel
      out:        (W, P) f32 block at graph g — the payload wave
    """
    if kernel.kind == "compute_mxu":
        w_ref, out_ref = rest
        mxu_w = w_ref[...]
    else:
        (out_ref,) = rest
        mxu_w = None
    t = pl.program_id(1)  # trailing grid dim: sequential on TPU

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    prev = out_ref[...]  # (W, P): the t-1 payload wave (zeros at t=0)
    width = prev.shape[0]
    row = pl.program_id(0) * height + t
    idx = idx_ref[row]    # (W, R)
    mask = mask_ref[row]  # (W, R)

    # dependency combine from the dense table: for each slot r, select
    # dep r's combined checksum out of the previous wave.  Each (i, r)
    # selects at most one column, so the f32 row-sum *is* that single
    # value exactly (< 2^20) — no integer reduction (Mosaic lacks one).
    prev_combined = jnp.transpose(prev[:, 3:4])  # (1, W)
    jcols = jax.lax.broadcasted_iota(jnp.int32, (width, width), 1)
    acc = jnp.zeros((width, 1), jnp.int32)
    for r in range(idx.shape[1]):
        sel = (idx[:, r:r + 1] == jcols) & (mask[:, r:r + 1] != 0)
        contrib = jnp.where(
            sel, jnp.broadcast_to(prev_combined, (width, width)),
            jnp.float32(0.0))
        picked = contrib.sum(axis=1, keepdims=True).astype(jnp.int32)
        acc = (acc + picked) % CHECKSUM_MOD

    base = base_ref[row]  # (W, 1)
    combined = (base + acc) % CHECKSUM_MOD
    iters = iters_ref[row]  # (W, 1)
    seed = acc.astype(jnp.float32) * jnp.float32(bodies.FOLD_BLOCK)
    res = bodies.run_kernel_columns(kernel, iters, seed, max_iters,
                                    mxu_w=mxu_w)  # (W, 1)

    tcol = jnp.zeros((width, 1), jnp.float32) + t.astype(jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.float32, (width, 1), 0)
    wave = jnp.concatenate(
        [tcol, cols, base.astype(jnp.float32),
         combined.astype(jnp.float32), res], axis=1)
    payload_elems = prev.shape[1]
    if payload_elems > 5:
        ballast = jnp.broadcast_to(res, (width, payload_elems - 5))
        wave = jnp.concatenate([wave, ballast], axis=1)
    out_ref[...] = wave


def _onesided_kernel(rank_ref, idx_ref, mask_ref, iters_ref, base_ref,
                     sel_ref, *rest, kernel: KernelSpec, height: int,
                     ndev: int, offsets, cap: int, max_iters: int):
    """One grid step = one timestep of one *rank's* column block.

    The persistent communicating kernel: dependency rows cross ranks via
    remote DMA puts into ``rbuf`` (the receive buffers, scratch slot per
    timestep × ring offset) with the DMA receive semaphore as the signal
    — ``putmem_signal``/``signal_wait_until`` — never via an XLA
    collective.  Refs:

      rank:       (1, 1) int32 — this rank's index on the mesh axis
      idx/mask:   (H, local, R) int32 — dep table in *context* coords
                  ``[recv slots (n_off * cap) | local block]``
      iters/base: (H, local, 1) int32
      sel:        (n_off, cap, local) f32 one-hot — which of this rank's
                  payload rows each put slot carries
      out:        (local, P) f32 — the rank's payload wave
      stage/rbuf: (H, n_off * cap, P) f32 scratch — send staging, inbox
      send/recv_sem: (H, n_off) DMA semaphores
    """
    if kernel.kind == "compute_mxu":
        w_ref, out_ref, *scratch = rest
        mxu_w = w_ref[...]
    else:
        out_ref, *scratch = rest
        mxu_w = None
    n_off = len(offsets)
    stage = rbuf = send_sem = recv_sem = None
    if n_off:
        stage, rbuf, send_sem, recv_sem = scratch
    t = pl.program_id(0)
    me = rank_ref[0, 0]

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def put(ts, oi, off):
        """The (ts, oi) put descriptor: my staged rows -> consumer's inbox."""
        dst = jax.lax.rem(me + off, ndev)
        return pltpu.make_async_remote_copy(
            src_ref=stage.at[ts, oi * cap:(oi + 1) * cap],
            dst_ref=rbuf.at[ts, oi * cap:(oi + 1) * cap],
            send_sem=send_sem.at[ts, oi],
            recv_sem=recv_sem.at[ts, oi],
            device_id=dst,
            device_id_type=pltpu.DeviceIdType.LOGICAL)

    if n_off:
        # signal_wait_until: epoch t-1's puts must have landed in our
        # inbox (recv sem) and our own sends drained (send sem)
        @pl.when(t > 0)
        def _wait():
            for oi, off in enumerate(offsets):
                put(t - 1, oi, off).wait_recv()
                put(t - 1, oi, off).wait_send()

    prev_wave = out_ref[...]  # (local, P): t-1 payloads (zeros at t=0)
    width = prev_wave.shape[0]
    if n_off:
        ctx = jnp.concatenate([rbuf[jnp.maximum(t - 1, 0)], prev_wave])
    else:
        ctx = prev_wave
    ctx_w = ctx.shape[0]

    # dependency combine exactly as the fused kernel, over the context
    # window; slots of dead pairs / the unwritten t=0 inbox are never
    # referenced by idx/mask, and the where() keeps their garbage out
    prev_combined = jnp.transpose(ctx[:, 3:4])  # (1, ctx_w)
    jcols = jax.lax.broadcasted_iota(jnp.int32, (width, ctx_w), 1)
    idx = idx_ref[t]    # (local, R)
    mask = mask_ref[t]  # (local, R)
    acc = jnp.zeros((width, 1), jnp.int32)
    for r in range(idx.shape[1]):
        sel = (idx[:, r:r + 1] == jcols) & (mask[:, r:r + 1] != 0)
        contrib = jnp.where(
            sel, jnp.broadcast_to(prev_combined, (width, ctx_w)),
            jnp.float32(0.0))
        picked = contrib.sum(axis=1, keepdims=True).astype(jnp.int32)
        acc = (acc + picked) % CHECKSUM_MOD

    base = base_ref[t]
    combined = (base + acc) % CHECKSUM_MOD
    iters = iters_ref[t]
    seed = acc.astype(jnp.float32) * jnp.float32(bodies.FOLD_BLOCK)
    res = bodies.run_kernel_columns(kernel, iters, seed, max_iters,
                                    mxu_w=mxu_w)  # (local, 1)

    tcol = jnp.zeros((width, 1), jnp.float32) + t.astype(jnp.float32)
    cols = (me * width
            + jax.lax.broadcasted_iota(jnp.int32, (width, 1), 0)
            ).astype(jnp.float32)
    wave = jnp.concatenate(
        [tcol, cols, base.astype(jnp.float32),
         combined.astype(jnp.float32), res], axis=1)
    payload_elems = prev_wave.shape[1]
    if payload_elems > 5:
        ballast = jnp.broadcast_to(res, (width, payload_elems - 5))
        wave = jnp.concatenate([wave, ballast], axis=1)
    out_ref[...] = wave

    if n_off:
        # the puts: every rank pushes to every active ring offset — the
        # SPMD-uniform one-sided schedule (dead pairs carry masked rows)
        @pl.when(t < height - 1)
        def _put():
            for oi, off in enumerate(offsets):
                block = jnp.dot(sel_ref[oi], wave,
                                preferred_element_type=jnp.float32)
                stage[t, oi * cap:(oi + 1) * cap] = block
                put(t, oi, off).start()


@register_backend("pallas-fused")
class MegakernelBackend(StackedProgramBackend):
    """Whole-graph fusion below the XLA dispatch floor."""

    paradigm = "persistent fused kernel (single launch per graph batch)"
    dispatch_model = "per-launch"

    def __init__(self, interpret: Optional[bool] = None,
                 comm: Optional[str] = None):
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if comm not in (None, "onesided"):
            raise ValueError(
                f"pallas-fused comm must be 'onesided' (or omitted for the "
                f"single-device fused kernel), got {comm!r}")
        self.interpret = bool(interpret)
        self.comm = comm
        if comm == "onesided":
            devs = np.array(jax.devices())
            self.mesh = Mesh(devs, ("cols",))
            self.ndev = len(devs)

    # -- table construction ------------------------------------------------
    @staticmethod
    def _tables(graphs: Sequence[TaskGraph], radix: int):
        """Host-side static inputs, graphs concatenated on the row axis."""
        idxs, masks, its, bases = [], [], [], []
        for g in graphs:
            idx, mask = g.dependency_table(radix)
            _, iters = body.graph_static_inputs(g)
            idxs.append(idx)
            masks.append(mask.astype(np.int32))
            its.append(iters[..., None])
            bases.append(g.checksum_table().astype(np.int32)[..., None])
        tabs = tuple(np.concatenate(x, axis=0)
                     for x in (idxs, masks, its, bases))
        if graphs[0].kernel.kind == "compute_mxu":
            tabs += (mxu_weight().astype(np.float32),)
        return tabs

    @staticmethod
    def _call(g0: TaskGraph, ngraphs: int, radix: int, interpret: bool):
        """The single-launch pallas_call for ``ngraphs`` stacked graphs."""
        W, H, P = g0.width, g0.height, g0.payload_elems
        table = lambda g, t: (0, 0, 0)  # whole tables stay resident
        in_specs = [
            pl.BlockSpec((ngraphs * H, W, radix), table),
            pl.BlockSpec((ngraphs * H, W, radix), table),
            pl.BlockSpec((ngraphs * H, W, 1), table),
            pl.BlockSpec((ngraphs * H, W, 1), table),
        ]
        if g0.kernel.kind == "compute_mxu":
            in_specs.append(
                pl.BlockSpec((MXU_DIM, MXU_DIM), lambda g, t: (0, 0)))
        return pl.pallas_call(
            functools.partial(_fused_kernel, kernel=g0.kernel, height=H,
                              max_iters=g0.kernel.iterations),
            grid=(ngraphs, H),
            in_specs=in_specs,
            # block index g, revisited for every t: the payload wave
            out_specs=pl.BlockSpec((W, P), lambda g, t: (g, 0)),
            out_shape=jax.ShapeDtypeStruct((ngraphs * W, P), jnp.float32),
            interpret=interpret,
        )

    # -- one-sided (distributed) tables and program ------------------------
    @staticmethod
    def _onesided_tables(graph: TaskGraph, plan: CC.CommPlan):
        """Per-rank static inputs for the communicating kernel.

        The dep table is rebuilt in *context* coordinates from the plan's
        ``local_mats`` (``[recv slots | local block]``), sliced per rank
        on a leading mesh axis; ``sel`` is the one-hot put schedule (which
        local payload rows each (offset, slot) put carries).
        """
        lm = plan.local_mats  # (H, padded, ctx) — plan coords, src-major
        H, padded, _ = lm.shape
        ndev, local, cap = plan.ndev, plan.local, plan.a2a_cap
        offsets = ([off for off, _, _ in plan._onesided_offsets]
                   if cap else [])
        n_off = len(offsets)
        oi_of = {off: oi for oi, off in enumerate(offsets)}
        radix = max(1, int(lm.sum(-1).max()))
        idx = np.zeros((ndev, H, local, radix), np.int32)
        mask = np.zeros((ndev, H, local, radix), np.int32)
        # remap plan context coords ([src-rank-major recv | local]) into
        # the kernel's inbox coords ([ring-offset-major recv | local]):
        # the put at offset ``off`` always lands in inbox slot block
        # ``oi_of[off]``, whatever the source rank — which is what keeps
        # every rank's DMA slices static and the schedule SPMD-uniform
        for t, i in zip(*np.nonzero(lm.any(-1))):
            d = i // local
            ks = []
            for c in np.nonzero(lm[t, i])[0]:
                if c >= ndev * cap:  # the local block
                    ks.append(n_off * cap + (c - ndev * cap))
                else:
                    s, k = c // cap, c % cap
                    ks.append(oi_of[(d - s) % ndev] * cap + k)
            idx[d, t, i - d * local, :len(ks)] = ks
            mask[d, t, i - d * local, :len(ks)] = 1
        base = np.zeros((H, padded), np.int64)
        base[:, :graph.width] = graph.checksum_table()

        def per_rank(a):  # (H, padded, X) -> (ndev, H, local, X)
            return np.ascontiguousarray(
                a.reshape(H, ndev, local, -1).transpose(1, 0, 2, 3))

        sel = np.zeros((ndev, max(n_off, 1), max(cap, 1), local),
                       np.float32)
        for oi, (_, idx_tab, _) in enumerate(plan._onesided_offsets
                                             if cap else []):
            for r in range(ndev):
                for k in range(cap):
                    sel[r, oi, k, idx_tab[r, k]] = 1.0
        tabs = (idx, mask,
                per_rank(plan.iters[..., None].astype(np.int32)),
                per_rank(base.astype(np.int32)[..., None]), sel)
        if graph.kernel.kind == "compute_mxu":
            tabs += (mxu_weight().astype(np.float32),)
        return offsets, tabs

    def _onesided_call(self, graph: TaskGraph, plan: CC.CommPlan,
                       offsets: List[int], radix: int, interpret: bool):
        """The per-rank single-launch pallas_call (grid over timesteps)."""
        H, local, Pels = graph.height, plan.local, graph.payload_elems
        cap, n_off = plan.a2a_cap, len(offsets)
        whole = lambda shape: pl.BlockSpec(
            shape, lambda t: (0,) * len(shape))
        in_specs = [
            # rank must live in SMEM: Mosaic needs a true scalar (not a
            # vector lane) to compute the remote-DMA device_id
            pl.BlockSpec(memory_space=pltpu.SMEM),
            whole((H, local, radix)),
            whole((H, local, radix)),
            whole((H, local, 1)),
            whole((H, local, 1)),
            whole((max(n_off, 1), max(cap, 1), local)),
        ]
        if graph.kernel.kind == "compute_mxu":
            in_specs.append(whole((MXU_DIM, MXU_DIM)))
        scratch = []
        if n_off:
            scratch = [
                pltpu.VMEM((H, n_off * cap, Pels), jnp.float32),  # stage
                pltpu.VMEM((H, n_off * cap, Pels), jnp.float32),  # rbuf
                pltpu.SemaphoreType.DMA((H, n_off)),
                pltpu.SemaphoreType.DMA((H, n_off)),
            ]
        return pl.pallas_call(
            functools.partial(
                _onesided_kernel, kernel=graph.kernel, height=H,
                ndev=plan.ndev, offsets=tuple(offsets), cap=cap,
                max_iters=graph.kernel.iterations),
            grid=(H,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((local, Pels), lambda t: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((local, Pels), jnp.float32),
            scratch_shapes=scratch,
            interpret=interpret,
        )

    def _program_onesided(self, graphs: List[TaskGraph], interpret: bool):
        """One persistent communicating kernel per rank per graph."""
        mesh, ndev = self.mesh, self.ndev
        ranks = jnp.arange(ndev, dtype=jnp.int32).reshape(ndev, 1)
        shards, args = [], []
        for g in graphs:
            plan = CC.plan_comm(g, ndev, "cols", comm="onesided")
            offsets, tabs = self._onesided_tables(g, plan)
            radix = tabs[0].shape[-1]
            call = self._onesided_call(g, plan, offsets, radix, interpret)
            n_tabs = len(tabs)

            def per_rank(rank, *tables, call=call, n_tabs=n_tabs):
                sharded = [a[0] for a in tables[:4]] + [tables[4][0]]
                if n_tabs > 5:
                    sharded.append(tables[5])  # mxu weight, replicated
                return call(rank, *sharded)

            in_specs = (P("cols", None),) + (P("cols"),) * 5
            if n_tabs > 5:
                in_specs += (P(None, None),)
            shards.append((shard_map(
                per_rank, mesh=mesh, in_specs=in_specs,
                out_specs=P("cols", None), check_vma=False), plan.width))
            args.append((ranks,) + tuple(jnp.asarray(a) for a in tabs))

        def program(all_args):
            return [fn(*a)[:w] for (fn, w), a in zip(shards, all_args)]

        return jax.jit(program), args

    # -- programs ----------------------------------------------------------
    def _program(self, graphs: List[TaskGraph], interpret: bool):
        """Independent graphs: one jit program, one launch per graph."""
        calls = [self._call(g, 1, max(1, g.max_radix()), interpret)
                 for g in graphs]
        args = [tuple(jnp.asarray(a)
                      for a in self._tables([g], max(1, g.max_radix())))
                for g in graphs]

        def program(all_tabs):
            return [call(*tabs) for call, tabs in zip(calls, all_tabs)]

        return jax.jit(program), args

    def _program_stacked(self, graphs: List[TaskGraph], interpret: bool):
        """Concurrent graphs in ONE launch: the graph axis is the leading
        grid dimension, so even multi-graph scenarios stay at dispatch
        count 1 (vs one scan per graph elsewhere)."""
        g0 = graphs[0]
        radix = max(1, max(g.max_radix() for g in graphs))
        call = self._call(g0, len(graphs), radix, interpret)
        tabs = tuple(jnp.asarray(a) for a in self._tables(graphs, radix))

        def program(*tabs_a):
            out = call(*tabs_a)  # (G*W, P)
            return out.reshape(len(graphs), g0.width, g0.payload_elems)

        return (jax.jit(program),) + tabs

    # -- StackedProgramBackend hooks --------------------------------------
    def _build(self, graphs: Sequence[TaskGraph]):
        if self.comm == "onesided":
            return self._program_onesided(list(graphs), self.interpret)
        return self._program(list(graphs), self.interpret)

    def _build_stacked(self, graphs: Sequence[TaskGraph]):
        if self.comm == "onesided" or not body.stackable(graphs):
            return None  # onesided: per-graph rank programs, no stacking
        return self._program_stacked(list(graphs), self.interpret)

    def lowered_stablehlo(self, graphs: Sequence[TaskGraph],
                          platforms: Sequence[str] = ("tpu",)) -> str:
        """Always lowers the real (non-interpret) kernel: the launch
        count being pinned is a property of the Mosaic program, not of
        the CPU-CI interpret fallback."""
        graphs = list(graphs)
        if self.comm == "onesided":
            built = self._program_onesided(graphs, False)
        elif body.stackable(graphs):
            built = self._program_stacked(graphs, False)
        else:
            built = self._program(graphs, False)
        fn, *args = built
        return fn.trace(*args).lower(
            lowering_platforms=tuple(platforms)).as_text()
