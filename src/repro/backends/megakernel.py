"""Persistent Pallas megakernel backend: one launch per task-graph batch.

Every other backend pays XLA's per-op dispatch on each timestep (a scan
iteration, an unrolled op chain, a host call per task) — exactly the
runtime overhead the paper identifies as the METG floor (§V-C: ~100 µs
even for the best runtimes).  Follow-up Task Bench studies show the METG
curve is *dominated* by this term, so the only way to move the curve is
to remove dispatches, not tune them.

This backend removes them: the whole task graph — all timesteps ×
columns, dependencies included — lowers into a *single* Pallas kernel
launch.

* The grid is ``(graphs, timesteps)``; TPU grids execute sequentially,
  so the trailing dimension is the timestep loop *inside* the kernel.
* The output block is revisited on every timestep of a graph and acts as
  the loop-carried payload wave: timestep ``t`` reads the block (the
  ``t-1`` payloads), resolves dependencies, and overwrites it.
* Dependencies are realized through that block — in-kernel VMEM reads
  indexed by the graph's dense dependency table
  (``TaskGraph.dependency_table``) — instead of XLA dataflow edges.
* The task body is ``kernels.bodies.run_kernel_columns``, the same
  traced code path the jitted backends execute, so conformance stays
  bit-exact.

Dispatch count per execution: 1 (vs H scan steps or H·W host calls).
``tests/test_megakernel.py`` pins this structurally: the TPU lowering of
the fused program contains exactly one kernel launch
(``tpu_custom_call``) and no ``stablehlo.while``, while ``xla-scan``'s
contains a while loop and no kernel launch.

CPU CI runs the kernel in Pallas interpret mode (``interpret=None``
auto-detects the platform); on TPU hosts Mosaic compiles the same kernel
— all in-kernel arithmetic keeps to Mosaic-legal forms (column-vector
shapes, int32 checksum math with the uint32 wrap-around base checksums
precomputed host-side via ``TaskGraph.checksum_table``; see
``kernels/bodies.py``).  The memory / compute_mxu task kernels are
validated in interpret mode only.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.graph import CHECKSUM_MOD, TaskGraph
from ..core.kernel_ref import mxu_weight
from ..core.kernel_spec import MXU_DIM, KernelSpec
from ..kernels import bodies
from . import body
from .base import StackedProgramBackend, register_backend


def _fused_kernel(idx_ref, mask_ref, iters_ref, base_ref, *rest,
                  kernel: KernelSpec, height: int, max_iters: int):
    """One grid step = one timestep of one graph, all columns.

    Refs (full-array blocks; G graphs share the leading table axis):
      idx/mask:   (G*H, W, R) int32 — dependency table rows
      iters:      (G*H, W, 1) int32 — per-task durations (imbalance)
      base:       (G*H, W, 1) int32 — precomputed base checksums
      [w]:        (MXU_DIM, MXU_DIM) f32 — only for the mxu kernel
      out:        (W, P) f32 block at graph g — the payload wave
    """
    if kernel.kind == "compute_mxu":
        w_ref, out_ref = rest
        mxu_w = w_ref[...]
    else:
        (out_ref,) = rest
        mxu_w = None
    t = pl.program_id(1)  # trailing grid dim: sequential on TPU

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    prev = out_ref[...]  # (W, P): the t-1 payload wave (zeros at t=0)
    width = prev.shape[0]
    row = pl.program_id(0) * height + t
    idx = idx_ref[row]    # (W, R)
    mask = mask_ref[row]  # (W, R)

    # dependency combine from the dense table: for each slot r, select
    # dep r's combined checksum out of the previous wave.  Each (i, r)
    # selects at most one column, so the f32 row-sum *is* that single
    # value exactly (< 2^20) — no integer reduction (Mosaic lacks one).
    prev_combined = jnp.transpose(prev[:, 3:4])  # (1, W)
    jcols = jax.lax.broadcasted_iota(jnp.int32, (width, width), 1)
    acc = jnp.zeros((width, 1), jnp.int32)
    for r in range(idx.shape[1]):
        sel = (idx[:, r:r + 1] == jcols) & (mask[:, r:r + 1] != 0)
        contrib = jnp.where(
            sel, jnp.broadcast_to(prev_combined, (width, width)),
            jnp.float32(0.0))
        picked = contrib.sum(axis=1, keepdims=True).astype(jnp.int32)
        acc = (acc + picked) % CHECKSUM_MOD

    base = base_ref[row]  # (W, 1)
    combined = (base + acc) % CHECKSUM_MOD
    iters = iters_ref[row]  # (W, 1)
    seed = acc.astype(jnp.float32) * jnp.float32(bodies.FOLD_BLOCK)
    res = bodies.run_kernel_columns(kernel, iters, seed, max_iters,
                                    mxu_w=mxu_w)  # (W, 1)

    tcol = jnp.zeros((width, 1), jnp.float32) + t.astype(jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.float32, (width, 1), 0)
    wave = jnp.concatenate(
        [tcol, cols, base.astype(jnp.float32),
         combined.astype(jnp.float32), res], axis=1)
    payload_elems = prev.shape[1]
    if payload_elems > 5:
        ballast = jnp.broadcast_to(res, (width, payload_elems - 5))
        wave = jnp.concatenate([wave, ballast], axis=1)
    out_ref[...] = wave


@register_backend("pallas-fused")
class MegakernelBackend(StackedProgramBackend):
    """Whole-graph fusion below the XLA dispatch floor."""

    paradigm = "persistent fused kernel (single launch per graph batch)"
    dispatch_model = "per-launch"

    def __init__(self, interpret: Optional[bool] = None):
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)

    # -- table construction ------------------------------------------------
    @staticmethod
    def _tables(graphs: Sequence[TaskGraph], radix: int):
        """Host-side static inputs, graphs concatenated on the row axis."""
        idxs, masks, its, bases = [], [], [], []
        for g in graphs:
            idx, mask = g.dependency_table(radix)
            _, iters = body.graph_static_inputs(g)
            idxs.append(idx)
            masks.append(mask.astype(np.int32))
            its.append(iters[..., None])
            bases.append(g.checksum_table().astype(np.int32)[..., None])
        tabs = tuple(np.concatenate(x, axis=0)
                     for x in (idxs, masks, its, bases))
        if graphs[0].kernel.kind == "compute_mxu":
            tabs += (mxu_weight().astype(np.float32),)
        return tabs

    @staticmethod
    def _call(g0: TaskGraph, ngraphs: int, radix: int, interpret: bool):
        """The single-launch pallas_call for ``ngraphs`` stacked graphs."""
        W, H, P = g0.width, g0.height, g0.payload_elems
        table = lambda g, t: (0, 0, 0)  # whole tables stay resident
        in_specs = [
            pl.BlockSpec((ngraphs * H, W, radix), table),
            pl.BlockSpec((ngraphs * H, W, radix), table),
            pl.BlockSpec((ngraphs * H, W, 1), table),
            pl.BlockSpec((ngraphs * H, W, 1), table),
        ]
        if g0.kernel.kind == "compute_mxu":
            in_specs.append(
                pl.BlockSpec((MXU_DIM, MXU_DIM), lambda g, t: (0, 0)))
        return pl.pallas_call(
            functools.partial(_fused_kernel, kernel=g0.kernel, height=H,
                              max_iters=g0.kernel.iterations),
            grid=(ngraphs, H),
            in_specs=in_specs,
            # block index g, revisited for every t: the payload wave
            out_specs=pl.BlockSpec((W, P), lambda g, t: (g, 0)),
            out_shape=jax.ShapeDtypeStruct((ngraphs * W, P), jnp.float32),
            interpret=interpret,
        )

    # -- programs ----------------------------------------------------------
    def _program(self, graphs: List[TaskGraph], interpret: bool):
        """Independent graphs: one jit program, one launch per graph."""
        calls = [self._call(g, 1, max(1, g.max_radix()), interpret)
                 for g in graphs]
        args = [tuple(jnp.asarray(a)
                      for a in self._tables([g], max(1, g.max_radix())))
                for g in graphs]

        def program(all_tabs):
            return [call(*tabs) for call, tabs in zip(calls, all_tabs)]

        return jax.jit(program), args

    def _program_stacked(self, graphs: List[TaskGraph], interpret: bool):
        """Concurrent graphs in ONE launch: the graph axis is the leading
        grid dimension, so even multi-graph scenarios stay at dispatch
        count 1 (vs one scan per graph elsewhere)."""
        g0 = graphs[0]
        radix = max(1, max(g.max_radix() for g in graphs))
        call = self._call(g0, len(graphs), radix, interpret)
        tabs = tuple(jnp.asarray(a) for a in self._tables(graphs, radix))

        def program(*tabs_a):
            out = call(*tabs_a)  # (G*W, P)
            return out.reshape(len(graphs), g0.width, g0.payload_elems)

        return (jax.jit(program),) + tabs

    # -- StackedProgramBackend hooks --------------------------------------
    def _build(self, graphs: Sequence[TaskGraph]):
        return self._program(list(graphs), self.interpret)

    def _build_stacked(self, graphs: Sequence[TaskGraph]):
        if not body.stackable(graphs):
            return None
        return self._program_stacked(list(graphs), self.interpret)

    def lowered_stablehlo(self, graphs: Sequence[TaskGraph],
                          platforms: Sequence[str] = ("tpu",)) -> str:
        """Always lowers the real (non-interpret) kernel: the launch
        count being pinned is a property of the Mosaic program, not of
        the CPU-CI interpret fallback."""
        graphs = list(graphs)
        if body.stackable(graphs):
            built = self._program_stacked(graphs, False)
        else:
            built = self._program(graphs, False)
        fn, *args = built
        return fn.trace(*args).lower(
            lowering_platforms=tuple(platforms)).as_text()
