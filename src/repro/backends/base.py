"""Backend interface: a 'programming system' in the paper's sense.

Each backend executes a list of concurrent task graphs (paper: multiple
graphs model task parallelism) and returns the final-timestep payload of
each.  ``runner`` returns a zero-arg callable that re-executes the prepared
workload and blocks until completion — the METG harness times that.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Type

import numpy as np

from ..core.graph import TaskGraph

_BACKENDS: Dict[str, Type["Backend"]] = {}


def register_backend(name: str):
    def deco(cls):
        cls.name = name
        _BACKENDS[name] = cls
        return cls

    return deco


def backend_names() -> List[str]:
    return sorted(_BACKENDS)


def get_backend(name: str, **kwargs) -> "Backend":
    if name not in _BACKENDS:
        raise KeyError(f"unknown backend {name!r}; known: {backend_names()}")
    return _BACKENDS[name](**kwargs)


class Backend:
    """Executes task graphs. Subclasses implement ``prepare``."""

    name = "base"
    # paper Table 4 analogue, reported by benchmarks:
    paradigm = ""

    def prepare(self, graphs: Sequence[TaskGraph]) -> Callable[[], List[np.ndarray]]:
        """Compile/stage the workload; returned callable blocks on finish."""
        raise NotImplementedError

    def run(self, graphs: Sequence[TaskGraph]) -> List[np.ndarray]:
        return self.prepare(graphs)()
