"""Backend interface: a 'programming system' in the paper's sense.

Each backend executes a list of concurrent task graphs (paper: multiple
graphs model task parallelism) and returns the final-timestep payload of
each.  ``runner`` returns a zero-arg callable that re-executes the prepared
workload and blocks until completion — the METG harness times that.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Type

import numpy as np

from ..core.graph import TaskGraph

_BACKENDS: Dict[str, Type["Backend"]] = {}


def register_backend(name: str):
    def deco(cls):
        cls.name = name
        _BACKENDS[name] = cls
        return cls

    return deco


def backend_names() -> List[str]:
    return sorted(_BACKENDS)


def get_backend(name: str, **kwargs) -> "Backend":
    if name not in _BACKENDS:
        raise KeyError(f"unknown backend {name!r}; known: {backend_names()}")
    return _BACKENDS[name](**kwargs)


class Backend:
    """Executes task graphs. Subclasses implement ``prepare``.

    ``prepare`` runs the graphs *independently* (one program each, or one
    sequential program); ``prepare_many`` is the concurrent entry point for
    multi-graph scenarios (paper Fig 9d: task parallelism) — backends that
    can overlap graphs override it (stacked graph dimension on the
    vectorized backends, interleaved wavefronts on host/CSP), and the
    default falls back to ``prepare``.
    """

    name = "base"
    # paper Table 4 analogue, reported by benchmarks:
    paradigm = ""

    def prepare(self, graphs: Sequence[TaskGraph]) -> Callable[[], List[np.ndarray]]:
        """Compile/stage the workload; returned callable blocks on finish."""
        raise NotImplementedError

    def prepare_many(self, graphs: Sequence[TaskGraph]) -> Callable[[], List[np.ndarray]]:
        """Stage ``graphs`` for *concurrent* execution (default: ``prepare``)."""
        return self.prepare(graphs)

    def run(self, graphs: Sequence[TaskGraph]) -> List[np.ndarray]:
        return self.prepare(graphs)()

    def run_many(self, graphs: Sequence[TaskGraph]) -> List[np.ndarray]:
        """Execute ``graphs`` concurrently; per-graph outputs, same order."""
        return self.prepare_many(graphs)()

    def lowered_hlo(self, graphs: Sequence[TaskGraph]) -> List[str]:
        """Optimized HLO of the compiled program(s) ``run_many`` executes.

        Empty when the backend has no whole-graph program (host dispatch).
        The dry-run timer feeds these to ``launch.roofline.analyze_hlo``.
        """
        return []


class StackedProgramBackend(Backend):
    """Shared scaffolding for single-device whole-program backends.

    Subclasses provide ``_compile(graphs) -> (compiled, *args)`` (one
    program, per-graph outputs) and ``_compile_stacked(graphs) ->
    (compiled, *args) | None`` (one program over a leading graph axis,
    when the graphs can share a task body); everything else — runners,
    the concurrent fallback, HLO exposure — lives here so the scan and
    dataflow backends cannot drift apart.
    """

    def _compile(self, graphs: Sequence[TaskGraph]):
        raise NotImplementedError

    def _compile_stacked(self, graphs: Sequence[TaskGraph]):
        return None  # no stacked form: prepare_many falls back to prepare

    def prepare(self, graphs: Sequence[TaskGraph]):
        import jax

        compiled, *args = self._compile(graphs)

        def runner() -> List[np.ndarray]:
            outs = compiled(*args)
            return [np.asarray(jax.block_until_ready(o)) for o in outs]

        return runner

    def prepare_many(self, graphs: Sequence[TaskGraph]):
        import jax

        graphs = list(graphs)
        built = self._compile_stacked(graphs)
        if built is None:
            return self.prepare(graphs)
        compiled, *args = built

        def runner() -> List[np.ndarray]:
            out = np.asarray(jax.block_until_ready(compiled(*args)))
            return [out[k] for k in range(out.shape[0])]

        return runner

    def lowered_hlo(self, graphs: Sequence[TaskGraph]) -> List[str]:
        graphs = list(graphs)
        built = self._compile_stacked(graphs)
        if built is not None:
            return [built[0].as_text()]
        return [self._compile(graphs)[0].as_text()]
