"""Backend interface: a 'programming system' in the paper's sense.

Each backend executes a list of concurrent task graphs (paper: multiple
graphs model task parallelism) and returns the final-timestep payload of
each.  ``runner`` returns a zero-arg callable that re-executes the prepared
workload and blocks until completion — the METG harness times that.
"""
from __future__ import annotations

import ast
import inspect
import re
from typing import Callable, Dict, List, Sequence, Tuple, Type

import numpy as np

from ..core.graph import TaskGraph

_BACKENDS: Dict[str, Type["Backend"]] = {}

# "name[key=value,key2=value2]" — the declarative backend-spec string.
# ScenarioSpec.backend and the Timer protocol carry a single string, so
# constructor options (schedule="steal", comm_overlap=True, comm="a2a")
# must be expressible inside it.
_SPEC_RE = re.compile(r"^([A-Za-z0-9_.-]+)(?:\[(.*)\])?$")


def register_backend(name: str):
    def deco(cls):
        cls.name = name
        _BACKENDS[name] = cls
        return cls

    return deco


def backend_names() -> List[str]:
    return sorted(_BACKENDS)


def parse_backend_spec(spec: str) -> Tuple[str, Dict[str, object]]:
    """Split ``"name[key=value,...]"`` into (name, constructor kwargs).

    Values parse as Python literals (``True``, ``4``, ``1.5``); bare
    words fall back to strings, so ``host-dynamic[schedule=steal]`` works
    without quoting.  A bare ``"name"`` parses to ``(name, {})``.

    The returned kwargs are *canonicalized* — sorted by key — so two
    spec strings that differ only in option order parse identically and
    ``canonical_backend_spec`` renders them to the same string (option
    order must never make two identical scenarios compare as different
    in the ``--baseline`` gate).
    """
    m = _SPEC_RE.match(spec)
    if m is None:
        raise ValueError(
            f"malformed backend spec {spec!r}; expected "
            f"'name' or 'name[key=value,...]'")
    name, kwstr = m.group(1), m.group(2)
    kwargs: Dict[str, object] = {}
    if kwstr:
        for part in kwstr.split(","):
            part = part.strip()
            if "=" not in part:
                raise ValueError(
                    f"malformed backend option {part!r} in {spec!r}; "
                    f"expected key=value")
            k, v = (s.strip() for s in part.split("=", 1))
            if not k:
                raise ValueError(f"empty option name in backend spec {spec!r}")
            if k in kwargs:
                # a duplicate is always a typo'd spec — the last value
                # silently winning would hide it
                raise ValueError(
                    f"duplicate option {k!r} in backend spec {spec!r}")
            if v.lower() in ("true", "false"):
                # accept the JSON/YAML spellings too: a bare 'false'
                # falling through to the string branch would be truthy
                kwargs[k] = v.lower() == "true"
                continue
            try:
                kwargs[k] = ast.literal_eval(v)
            except (ValueError, SyntaxError):
                kwargs[k] = v  # bare word: a string (steal, a2a, ...)
    return name, dict(sorted(kwargs.items()))


def canonical_backend_spec(spec: str) -> str:
    """The canonical rendering of a backend spec string.

    Parses and re-renders with options sorted by key (bools/numbers in
    Python spelling, strings as bare words), so key-reordered spellings
    of the same spec — ``"x[a=1,b=2]"`` vs ``"x[b=2,a=1]"`` — map to one
    identity.  ``bench.compare`` compares scenario backends through this
    so a reordered baseline never reads as a vanished scenario.
    """
    name, kwargs = parse_backend_spec(spec)
    if not kwargs:
        return name
    opts = ",".join(f"{k}={v}" for k, v in kwargs.items())
    return f"{name}[{opts}]"


def backend_option_signature(name: str) -> Dict[str, object]:
    """The registered backend's constructor options and their defaults.

    Maps option name -> default value (``inspect.Parameter.empty`` for
    required options).  This is the *known-options metadata* the spec
    validator rejects typos against and the tuner
    (``repro.bench.tuner.enumerate_mode_space``) prunes the legal
    backend/mode space with — one source of truth, the constructor
    signature itself.  Returns ``None`` when the constructor takes open
    ``**kwargs`` (it validates its own options).
    """
    if name not in _BACKENDS:
        raise KeyError(f"unknown backend {name!r}; known: {backend_names()}")
    init = _BACKENDS[name].__init__
    if init is object.__init__:
        return {}
    params = inspect.signature(init).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return None
    return {n: p.default for n, p in params.items()
            if n != "self" and p.kind in (
                inspect.Parameter.POSITIONAL_OR_KEYWORD,
                inspect.Parameter.KEYWORD_ONLY)}


def _check_ctor_kwargs(cls: Type["Backend"], name: str, kwargs: Dict) -> None:
    """Reject unknown constructor options, naming backend and key.

    A typo'd option (``sched=steal`` for ``schedule``) must fail loudly,
    not no-op — and the raw ``TypeError`` from ``cls(**kwargs)`` would
    name the class, not the backend the spec string asked for.
    """
    if not kwargs:
        return
    sig = backend_option_signature(name)
    if sig is None:
        return  # the constructor validates its own open kwargs
    known = list(sig)
    for k in kwargs:
        if k not in known:
            raise ValueError(
                f"backend {name!r} does not accept option {k!r}; "
                f"known options: {known if known else 'none'}")


def get_backend(name: str, **kwargs) -> "Backend":
    """Instantiate a backend from a name or spec string.

    Explicit keyword arguments override options embedded in the spec
    string: ``get_backend("shardmap-csp[comm=a2a]", comm="halo")`` builds
    a halo-mode backend.
    """
    base, spec_kw = parse_backend_spec(name)
    if base not in _BACKENDS:
        raise KeyError(f"unknown backend {base!r}; known: {backend_names()}")
    cls = _BACKENDS[base]
    merged = {**spec_kw, **kwargs}
    _check_ctor_kwargs(cls, base, merged)
    return cls(**merged)


class Backend:
    """Executes task graphs. Subclasses implement ``prepare``.

    ``prepare`` runs the graphs *independently* (one program each, or one
    sequential program); ``prepare_many`` is the concurrent entry point for
    multi-graph scenarios (paper Fig 9d: task parallelism) — backends that
    can overlap graphs override it (stacked graph dimension on the
    vectorized backends, interleaved wavefronts on host/CSP), and the
    default falls back to ``prepare``.
    """

    name = "base"
    # paper Table 4 analogue, reported by benchmarks:
    paradigm = ""
    # deterministic-model hints consumed by bench.timers.SyntheticTimer:
    # how this backend lays a wavefront's tasks over workers
    # (core.schedule policy), and whether it issues the next step's
    # communication ahead of the current kernel body (double buffering)
    sched_policy = "static"
    comm_overlap = False
    # which dispatch-cost model this backend's execution implies:
    # "per-task" — every task pays the runtime's dispatch overhead (the
    # paper's model, and XLA's per-op reality); "per-launch" — one fixed
    # launch cost for the whole graph batch (the fused megakernel).
    # Resolved leniently by name (bench.timers.backend_dispatch_model),
    # never by instantiation, so the default synthetic configuration
    # stays backend-free.
    dispatch_model = "per-task"

    def prepare(self, graphs: Sequence[TaskGraph]) -> Callable[[], List[np.ndarray]]:
        """Compile/stage the workload; returned callable blocks on finish."""
        raise NotImplementedError

    def prepare_many(self, graphs: Sequence[TaskGraph]) -> Callable[[], List[np.ndarray]]:
        """Stage ``graphs`` for *concurrent* execution (default: ``prepare``)."""
        return self.prepare(graphs)

    def run(self, graphs: Sequence[TaskGraph]) -> List[np.ndarray]:
        return self.prepare(graphs)()

    def run_many(self, graphs: Sequence[TaskGraph]) -> List[np.ndarray]:
        """Execute ``graphs`` concurrently; per-graph outputs, same order."""
        return self.prepare_many(graphs)()

    def lowered_hlo(self, graphs: Sequence[TaskGraph]) -> List[str]:
        """Optimized HLO of the compiled program(s) ``run_many`` executes.

        Empty when the backend has no whole-graph program (host dispatch).
        The dry-run timer feeds these to ``launch.roofline.analyze_hlo``.
        """
        return []


class StackedProgramBackend(Backend):
    """Shared scaffolding for single-device whole-program backends.

    Subclasses provide ``_build(graphs) -> (jitted_fn, *args)`` (one
    program, per-graph outputs) and ``_build_stacked(graphs) ->
    (jitted_fn, *args) | None`` (one program over a leading graph axis,
    when the graphs can share a task body); everything else — AOT
    compilation, runners, the concurrent fallback, HLO/StableHLO
    exposure — lives here so the scan, dataflow and megakernel backends
    cannot drift apart.
    """

    def _build(self, graphs: Sequence[TaskGraph]):
        raise NotImplementedError

    def _build_stacked(self, graphs: Sequence[TaskGraph]):
        return None  # no stacked form: prepare_many falls back to prepare

    def _compile(self, graphs: Sequence[TaskGraph]):
        fn, *args = self._build(graphs)
        return (fn.lower(*args).compile(), *args)

    def _compile_stacked(self, graphs: Sequence[TaskGraph]):
        built = self._build_stacked(graphs)
        if built is None:
            return None
        fn, *args = built
        return (fn.lower(*args).compile(), *args)

    def prepare(self, graphs: Sequence[TaskGraph]):
        import jax

        compiled, *args = self._compile(graphs)

        def runner() -> List[np.ndarray]:
            outs = compiled(*args)
            return [np.asarray(jax.block_until_ready(o)) for o in outs]

        return runner

    def prepare_many(self, graphs: Sequence[TaskGraph]):
        import jax

        graphs = list(graphs)
        built = self._compile_stacked(graphs)
        if built is None:
            return self.prepare(graphs)
        compiled, *args = built

        def runner() -> List[np.ndarray]:
            out = np.asarray(jax.block_until_ready(compiled(*args)))
            return [out[k] for k in range(out.shape[0])]

        return runner

    def lowered_hlo(self, graphs: Sequence[TaskGraph]) -> List[str]:
        graphs = list(graphs)
        built = self._compile_stacked(graphs)
        if built is not None:
            return [built[0].as_text()]
        return [self._compile(graphs)[0].as_text()]

    def lowered_stablehlo(self, graphs: Sequence[TaskGraph],
                          platforms: Sequence[str] = ("tpu",)) -> str:
        """Pre-optimization StableHLO of the concurrent program,
        cross-lowered for ``platforms`` (no such hardware needed — jax
        lowers for TPU on a CPU-only host).

        Unlike ``lowered_hlo`` (optimized HLO of the program *compiled
        for the host platform*), this exposes the structural form the
        fusion tests count kernel launches in: ``tpu_custom_call`` sites
        (one per Pallas launch) and ``stablehlo.while`` loops (one per
        ``lax.scan`` dispatch loop).
        """
        graphs = list(graphs)
        built = self._build_stacked(graphs)
        if built is None:
            built = self._build(graphs)
        fn, *args = built
        return fn.trace(*args).lower(
            lowering_platforms=tuple(platforms)).as_text()
