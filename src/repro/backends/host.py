"""Host-dynamic backend: one dispatch per task from the Python host.

Analogue of the paper's dynamic, centrally-scheduled systems (Dask, Spark,
Swift/T): every task is a separate device invocation issued by the host,
with payload gather/scatter through host memory.  This is the high-overhead
end of the METG spectrum — per-task cost is dominated by dispatch, exactly
like the paper's §V-C findings for data-analytics systems.

Two executor schedules (paper §V-G, the load-imbalance study):

``schedule="static"``
    Column-order dispatch — each wavefront's tasks issue in static column
    ownership order, the per-task analogue of an MPI rank walking its
    block.

``schedule="steal"``
    Work-stealing dispatch — each wavefront's tasks issue in the greedy
    claim order of ``core.schedule.steal_schedule``: whenever a simulated
    worker goes idle it claims the longest unclaimed task, so imbalanced
    wavefronts re-pack instead of waiting on the slowest static block.
    Values are bit-identical to static (only issue *order* changes);
    the deterministic fake clock (``SyntheticTimer(workers=...)``) charges
    the matching makespan, which is where the mitigation shows up.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import CHECKSUM_MOD, TaskGraph
from ..core.schedule import steal_schedule
from . import body
from .base import Backend, register_backend

SCHEDULES = ("static", "steal")


@register_backend("host-dynamic")
class HostBackend(Backend):
    paradigm = "dynamic per-task host dispatch (Dask/Spark analogue)"

    def __init__(self, schedule: str = "static", workers: int = 4):
        if schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {schedule!r}; known: {SCHEDULES}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.schedule = schedule
        self.workers = workers
        self.sched_policy = "steal" if schedule == "steal" else "static"

    def _wavefront_order(self, graph: TaskGraph, iters: np.ndarray,
                         t: int) -> List[int]:
        """Column issue order for timestep ``t`` under this schedule."""
        if self.schedule == "static":
            return list(range(graph.width))
        return steal_schedule(iters[t].astype(np.float64), self.workers)[0]

    def _wavefront_orders(self, graph: TaskGraph,
                          iters: np.ndarray) -> List[List[int]]:
        """Issue order of every wavefront, precomputed at prepare time so
        the timed runner pays dispatch only (the claim order is a pure
        function of the graph — recomputing it per run would charge the
        steal schedule scheduling overhead static never pays)."""
        return [self._wavefront_order(graph, iters, t)
                for t in range(graph.height)]

    def dispatch_order(self, graph: TaskGraph) -> List[Tuple[int, int]]:
        """The full (t, i) issue sequence ``prepare`` walks (pure, no jax).

        Wavefronts issue strictly in timestep order — all dependencies
        live in t-1, so any within-wavefront permutation is legal — which
        is what the work-stealing property tests assert.
        """
        _, iters = body.graph_static_inputs(graph)
        return [(t, i)
                for t, order in enumerate(self._wavefront_orders(graph, iters))
                for i in order]

    def _dispatch_timestep(self, g: TaskGraph, fn, iters, store, t: int,
                           radix: int, order: Sequence[int]):
        """Issue every task of timestep ``t`` (and retire timestep t-2)."""
        for i in order:
            deps = g.deps(t, i)
            pads = jnp.zeros((radix, g.payload_elems), jnp.float32)
            if deps:
                stacked = jnp.stack([store[(t - 1, j)] for j in deps])
                pads = pads.at[: len(deps)].set(stacked)
            store[(t, i)] = fn(
                jnp.uint32(t),
                jnp.uint32(i),
                jnp.int32(iters[t, i]),
                pads,
                jnp.int32(len(deps)),
            )
        for i in range(g.width):
            store.pop((t - 2, i), None)

    def prepare(self, graphs: Sequence[TaskGraph]):
        task_fns = [self._compile_task(g) for g in graphs]
        statics = [body.graph_static_inputs(g) for g in graphs]
        orders = [self._wavefront_orders(g, iters)
                  for g, (mats, iters) in zip(graphs, statics)]

        def runner() -> List[np.ndarray]:
            finals: List[np.ndarray] = []
            for g, fn, (mats, iters), g_orders in zip(
                    graphs, task_fns, statics, orders):
                radix = max(1, g.max_radix())
                store: Dict[Tuple[int, int], jax.Array] = {}
                for t in range(g.height):
                    self._dispatch_timestep(g, fn, iters, store, t, radix,
                                            g_orders[t])
                row = jnp.stack([store[(g.height - 1, i)] for i in range(g.width)])
                finals.append(np.asarray(jax.block_until_ready(row)))
            return finals

        return runner

    def prepare_many(self, graphs: Sequence[TaskGraph]):
        """Concurrent execution: wavefronts of the graphs interleave.

        A dynamic scheduler with several ready task graphs issues whichever
        tasks are runnable; here the host walks timesteps outermost and
        dispatches every graph's timestep-t tasks before any graph's t+1,
        so the async JAX dispatch queue holds work from all graphs at once
        (the paper's task-parallelism scenario, Fig 9d).
        """
        graphs = list(graphs)
        if len(graphs) <= 1:
            return self.prepare(graphs)
        task_fns = [self._compile_task(g) for g in graphs]
        statics = [body.graph_static_inputs(g) for g in graphs]
        radii = [max(1, g.max_radix()) for g in graphs]
        orders = [self._wavefront_orders(g, iters)
                  for g, (mats, iters) in zip(graphs, statics)]

        def runner() -> List[np.ndarray]:
            stores: List[Dict[Tuple[int, int], jax.Array]] = [
                {} for _ in graphs]
            for t in range(max(g.height for g in graphs)):
                for g, fn, (mats, iters), store, radix, g_orders in zip(
                        graphs, task_fns, statics, stores, radii, orders):
                    if t < g.height:
                        self._dispatch_timestep(g, fn, iters, store, t, radix,
                                                g_orders[t])
            finals: List[np.ndarray] = []
            for g, store in zip(graphs, stores):
                row = jnp.stack(
                    [store[(g.height - 1, i)] for i in range(g.width)])
                finals.append(np.asarray(jax.block_until_ready(row)))
            return finals

        return runner

    @staticmethod
    def _compile_task(graph: TaskGraph):
        """One jitted function per graph spec, shared by all its tasks.

        Task duration is a *traced* argument so imbalanced graphs do not
        trigger recompiles (the kernel loop uses a dynamic trip count).
        """
        radix = max(1, graph.max_radix())

        @jax.jit
        def task(t, i, iters, inputs, nvalid):
            mask = jnp.arange(radix) < nvalid
            acc = (inputs[:, 3].astype(jnp.uint32) * mask.astype(jnp.uint32)).sum()
            acc = (acc % jnp.uint32(CHECKSUM_MOD))[None]
            base = body.checksum_vec(t, i[None])
            combined = (base + acc) % jnp.uint32(CHECKSUM_MOD)
            result = body.run_kernel_vec(
                graph.kernel, iters[None], acc, graph.kernel.iterations,
                dynamic=True,
            )
            head = jnp.stack([
                t.astype(jnp.float32),
                i.astype(jnp.float32),
                base[0].astype(jnp.float32),
                combined[0].astype(jnp.float32),
                result[0],
            ])
            if graph.payload_elems > 5:
                ballast = jnp.broadcast_to(result, (graph.payload_elems - 5,))
                return jnp.concatenate([head, ballast])
            return head

        return task
