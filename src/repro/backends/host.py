"""Host-dynamic backend: one dispatch per task from the Python host.

Analogue of the paper's dynamic, centrally-scheduled systems (Dask, Spark,
Swift/T): every task is a separate device invocation issued by the host,
with payload gather/scatter through host memory.  This is the high-overhead
end of the METG spectrum — per-task cost is dominated by dispatch, exactly
like the paper's §V-C findings for data-analytics systems.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import CHECKSUM_MOD, TaskGraph
from . import body
from .base import Backend, register_backend


@register_backend("host-dynamic")
class HostBackend(Backend):
    paradigm = "dynamic per-task host dispatch (Dask/Spark analogue)"

    @staticmethod
    def _dispatch_timestep(g: TaskGraph, fn, iters, store, t: int, radix: int):
        """Issue every task of timestep ``t`` (and retire timestep t-2)."""
        for i in range(g.width):
            deps = g.deps(t, i)
            pads = jnp.zeros((radix, g.payload_elems), jnp.float32)
            if deps:
                stacked = jnp.stack([store[(t - 1, j)] for j in deps])
                pads = pads.at[: len(deps)].set(stacked)
            store[(t, i)] = fn(
                jnp.uint32(t),
                jnp.uint32(i),
                jnp.int32(iters[t, i]),
                pads,
                jnp.int32(len(deps)),
            )
        for i in range(g.width):
            store.pop((t - 2, i), None)

    def prepare(self, graphs: Sequence[TaskGraph]):
        task_fns = [self._compile_task(g) for g in graphs]
        statics = [body.graph_static_inputs(g) for g in graphs]

        def runner() -> List[np.ndarray]:
            finals: List[np.ndarray] = []
            for g, fn, (mats, iters) in zip(graphs, task_fns, statics):
                radix = max(1, g.max_radix())
                store: Dict[Tuple[int, int], jax.Array] = {}
                for t in range(g.height):
                    self._dispatch_timestep(g, fn, iters, store, t, radix)
                row = jnp.stack([store[(g.height - 1, i)] for i in range(g.width)])
                finals.append(np.asarray(jax.block_until_ready(row)))
            return finals

        return runner

    def prepare_many(self, graphs: Sequence[TaskGraph]):
        """Concurrent execution: wavefronts of the graphs interleave.

        A dynamic scheduler with several ready task graphs issues whichever
        tasks are runnable; here the host walks timesteps outermost and
        dispatches every graph's timestep-t tasks before any graph's t+1,
        so the async JAX dispatch queue holds work from all graphs at once
        (the paper's task-parallelism scenario, Fig 9d).
        """
        graphs = list(graphs)
        if len(graphs) <= 1:
            return self.prepare(graphs)
        task_fns = [self._compile_task(g) for g in graphs]
        statics = [body.graph_static_inputs(g) for g in graphs]
        radii = [max(1, g.max_radix()) for g in graphs]

        def runner() -> List[np.ndarray]:
            stores: List[Dict[Tuple[int, int], jax.Array]] = [
                {} for _ in graphs]
            for t in range(max(g.height for g in graphs)):
                for g, fn, (mats, iters), store, radix in zip(
                        graphs, task_fns, statics, stores, radii):
                    if t < g.height:
                        self._dispatch_timestep(g, fn, iters, store, t, radix)
            finals: List[np.ndarray] = []
            for g, store in zip(graphs, stores):
                row = jnp.stack(
                    [store[(g.height - 1, i)] for i in range(g.width)])
                finals.append(np.asarray(jax.block_until_ready(row)))
            return finals

        return runner

    @staticmethod
    def _compile_task(graph: TaskGraph):
        """One jitted function per graph spec, shared by all its tasks.

        Task duration is a *traced* argument so imbalanced graphs do not
        trigger recompiles (the kernel loop uses a dynamic trip count).
        """
        radix = max(1, graph.max_radix())

        @jax.jit
        def task(t, i, iters, inputs, nvalid):
            mask = jnp.arange(radix) < nvalid
            acc = (inputs[:, 3].astype(jnp.uint32) * mask.astype(jnp.uint32)).sum()
            acc = (acc % jnp.uint32(CHECKSUM_MOD))[None]
            base = body.checksum_vec(t, i[None])
            combined = (base + acc) % jnp.uint32(CHECKSUM_MOD)
            result = body.run_kernel_vec(
                graph.kernel, iters[None], acc, graph.kernel.iterations,
                dynamic=True,
            )
            head = jnp.stack([
                t.astype(jnp.float32),
                i.astype(jnp.float32),
                base[0].astype(jnp.float32),
                combined[0].astype(jnp.float32),
                result[0],
            ])
            if graph.payload_elems > 5:
                ballast = jnp.broadcast_to(result, (graph.payload_elems - 5,))
                return jnp.concatenate([head, ballast])
            return head

        return task
