"""Scan backend: ``lax.scan`` over timesteps, columns vectorized.

Analogue of the paper's vectorized on-node runtimes (OpenMP forall /
MPI+OpenMP inner loop): one compiled timestep body re-executed H times.
Compile cost is O(1) in graph height (unlike xla-static), at the price of a
loop-carried schedule that XLA cannot fuse across timesteps.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import TaskGraph
from . import body
from .base import Backend, register_backend


@register_backend("xla-scan")
class ScanBackend(Backend):
    paradigm = "compiled timestep loop (OpenMP-forall analogue)"

    def prepare(self, graphs: Sequence[TaskGraph]):
        statics = [body.graph_static_inputs(g) for g in graphs]

        def program(all_mats, all_iters):
            outs = []
            for g, mats, iters in zip(graphs, all_mats, all_iters):
                init = jnp.zeros((g.width, g.payload_elems), jnp.float32)
                ts = jnp.arange(g.height, dtype=jnp.uint32)

                def step(payload, xs):
                    t, mat, it = xs
                    new = body.timestep(g, t, payload, mat, it)
                    return new, None

                final, _ = jax.lax.scan(step, init, (ts, mats, iters))
                outs.append(final)
            return outs

        fn = jax.jit(program)
        mats_in = [jnp.asarray(m) for m, _ in statics]
        iters_in = [jnp.asarray(i) for _, i in statics]
        compiled = fn.lower(mats_in, iters_in).compile()

        def runner() -> List[np.ndarray]:
            outs = compiled(mats_in, iters_in)
            return [np.asarray(jax.block_until_ready(o)) for o in outs]

        return runner
