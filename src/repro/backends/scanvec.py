"""Scan backend: ``lax.scan`` over timesteps, columns vectorized.

Analogue of the paper's vectorized on-node runtimes (OpenMP forall /
MPI+OpenMP inner loop): one compiled timestep body re-executed H times.
Compile cost is O(1) in graph height (unlike xla-static), at the price of a
loop-carried schedule that XLA cannot fuse across timesteps.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import TaskGraph
from . import body
from .base import StackedProgramBackend, register_backend


@register_backend("xla-scan")
class ScanBackend(StackedProgramBackend):
    paradigm = "compiled timestep loop (OpenMP-forall analogue)"

    def _build(self, graphs: Sequence[TaskGraph]):
        """One program scanning each graph in turn (independent execution)."""
        statics = [body.graph_static_inputs(g) for g in graphs]

        def program(all_mats, all_iters):
            outs = []
            for g, mats, iters in zip(graphs, all_mats, all_iters):
                init = jnp.zeros((g.width, g.payload_elems), jnp.float32)
                ts = jnp.arange(g.height, dtype=jnp.uint32)

                def step(payload, xs):
                    t, mat, it = xs
                    new = body.timestep(g, t, payload, mat, it)
                    return new, None

                final, _ = jax.lax.scan(step, init, (ts, mats, iters))
                outs.append(final)
            return outs

        mats_in = [jnp.asarray(m) for m, _ in statics]
        iters_in = [jnp.asarray(i) for _, i in statics]
        return jax.jit(program), mats_in, iters_in

    def _build_stacked(self, graphs: Sequence[TaskGraph]):
        """One scan over a stacked (graph, width) payload — the concurrent
        form: all graphs advance in the same compiled timestep (multi-graph
        scenarios, paper Fig 9d).  None if the graphs cannot share a body."""
        if not body.stackable(graphs):
            return None
        g0 = graphs[0]
        mats, iters = body.stacked_static_inputs(graphs)
        mats_t = jnp.asarray(mats.transpose(1, 0, 2, 3))  # (H, G, W, W)
        iters_t = jnp.asarray(iters.transpose(1, 0, 2))   # (H, G, W)

        def program(mats_a, iters_a):
            init = jnp.zeros((len(graphs), g0.width, g0.payload_elems),
                             jnp.float32)
            ts = jnp.arange(g0.height, dtype=jnp.uint32)

            def step(payload, xs):
                t, mat, it = xs
                new = jax.vmap(
                    lambda p, m, iv: body.timestep(g0, t, p, m, iv)
                )(payload, mat, it)
                return new, None

            final, _ = jax.lax.scan(step, init, (ts, mats_a, iters_a))
            return final

        return jax.jit(program), mats_t, iters_t
