"""Static-dataflow backend: the whole task graph is one XLA program.

Analogue of the paper's *statically compiled* systems (PaRSEC PTG, Regent
control replication, TensorFlow graphs): the schedule is fixed ahead of
time, per-task runtime overhead is ~zero, and the cost moves to compile
time.  Timesteps are unrolled into the program; columns are vectorized.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import TaskGraph
from . import body
from .base import StackedProgramBackend, register_backend


@register_backend("xla-static")
class DataflowBackend(StackedProgramBackend):
    paradigm = "static dataflow (PTG/Regent analogue)"

    def __init__(self, donate: bool = True):
        self.donate = donate

    def _build(self, graphs: Sequence[TaskGraph]):
        statics = [body.graph_static_inputs(g) for g in graphs]

        def program(all_mats, all_iters):
            outs = []
            for g, mats, iters in zip(graphs, all_mats, all_iters):
                payload = jnp.zeros((g.width, g.payload_elems), jnp.float32)
                for t in range(g.height):  # unrolled: static schedule
                    payload = body.timestep(g, t, payload, mats[t], iters[t])
                outs.append(payload)
            return outs

        mats_in = [jnp.asarray(m) for m, _ in statics]
        iters_in = [jnp.asarray(i) for _, i in statics]
        return jax.jit(program), mats_in, iters_in

    def _build_stacked(self, graphs: Sequence[TaskGraph]):
        """Concurrent form: the unrolled schedule advances a stacked
        (graph, width) payload, so every timestep of every graph sits in one
        static program and XLA schedules them together.  None if the graphs
        cannot share a task body."""
        if not body.stackable(graphs):
            return None
        g0 = graphs[0]
        mats, iters = body.stacked_static_inputs(graphs)
        mats_in = jnp.asarray(mats)    # (G, H, W, W)
        iters_in = jnp.asarray(iters)  # (G, H, W)

        def program(mats_a, iters_a):
            payload = jnp.zeros((len(graphs), g0.width, g0.payload_elems),
                                jnp.float32)
            for t in range(g0.height):  # unrolled: static schedule
                payload = jax.vmap(
                    lambda p, m, iv: body.timestep(g0, t, p, m, iv)
                )(payload, mats_a[:, t], iters_a[:, t])
            return payload

        return jax.jit(program), mats_in, iters_in
