"""Static-dataflow backend: the whole task graph is one XLA program.

Analogue of the paper's *statically compiled* systems (PaRSEC PTG, Regent
control replication, TensorFlow graphs): the schedule is fixed ahead of
time, per-task runtime overhead is ~zero, and the cost moves to compile
time.  Timesteps are unrolled into the program; columns are vectorized.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import TaskGraph
from . import body
from .base import Backend, register_backend


@register_backend("xla-static")
class DataflowBackend(Backend):
    paradigm = "static dataflow (PTG/Regent analogue)"

    def __init__(self, donate: bool = True):
        self.donate = donate

    def prepare(self, graphs: Sequence[TaskGraph]):
        statics = [body.graph_static_inputs(g) for g in graphs]

        def program(all_mats, all_iters):
            outs = []
            for g, mats, iters in zip(graphs, all_mats, all_iters):
                payload = jnp.zeros((g.width, g.payload_elems), jnp.float32)
                for t in range(g.height):  # unrolled: static schedule
                    payload = body.timestep(g, t, payload, mats[t], iters[t])
                outs.append(payload)
            return outs

        fn = jax.jit(program)
        mats_in = [jnp.asarray(m) for m, _ in statics]
        iters_in = [jnp.asarray(i) for _, i in statics]
        compiled = fn.lower(mats_in, iters_in).compile()

        def runner() -> List[np.ndarray]:
            outs = compiled(mats_in, iters_in)
            return [np.asarray(jax.block_until_ready(o)) for o in outs]

        return runner
