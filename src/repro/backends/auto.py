"""``get_backend("auto")``: table-driven backend selection.

The paper's core finding is that no single runtime wins everywhere —
the fastest system flips with task granularity, dependence pattern,
payload size and node count (§V).  This backend closes the loop: at
dispatch time it reduces the workload to its tuning key
(``repro.bench.tuner.graphs_cutout``), looks the key up in the committed
tuning table (``benchmarks/tuning/TUNE_default.json``, regenerated with
``python -m benchmarks.run --tune``), and delegates every ``prepare`` /
``prepare_many`` / ``lowered_hlo`` call to the winning backend.

Resolution is a pure table lookup — **zero per-dispatch measurement** —
with deterministic nearest-key semantics on a miss (exact key, then
nearest bucket within the same graph shape, then nearest same-pattern
key; see ``TuningTable.resolve_entry``) and a documented fallback
(``tuner.DEFAULT_FALLBACK``) when the table has never seen the pattern
or there is no table at all.  Because execution is pure delegation,
``auto`` is bit-exact with whatever backend it resolves to and joins
the conformance matrix like any other backend.

Options (the ``auto[key=value]`` spec grammar):

``table=<path>``
    An explicit ``TUNE_*.json`` to consult.  Must exist and validate —
    pointing at a missing/corrupt table is a configuration error, not a
    silent fallback.  Default: the committed repo table (absent is fine;
    every dispatch then uses the fallback).
``timer=<name>``
    Which timer the consulted table must have been tuned on (default
    ``synthetic``).  A mismatched table is refused — wall-clock winners
    and fake-clock winners are different claims.
``fallback=<spec>``
    What a table miss dispatches (default ``xla-scan`` — the vectorized
    backend that runs every pattern with no mode prerequisites).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.graph import TaskGraph
from .base import (Backend, backend_names, get_backend, parse_backend_spec,
                   register_backend)


@register_backend("auto")
class AutoBackend(Backend):
    """Delegates execution to the tuning table's winner for the workload.

    The planner in front of the paper's 'n systems': holds no execution
    machinery of its own, so conformance is delegation-exact by
    construction.
    """

    paradigm = "self-tuning planner (table-driven dispatch)"

    def __init__(self, table: Optional[str] = None,
                 fallback: Optional[str] = None,
                 timer: str = "synthetic"):
        from ..bench.tuner import DEFAULT_FALLBACK, load_tuning_table

        if fallback is None:
            fallback = DEFAULT_FALLBACK
        base, _ = parse_backend_spec(fallback)
        if base == "auto":
            raise ValueError("backend 'auto' cannot fall back to itself")
        if base not in backend_names():
            raise ValueError(
                f"auto fallback names unknown backend {base!r}; "
                f"known: {backend_names()}")
        self.fallback = fallback
        self.timer = timer
        # eager load: an explicit table= that is missing or corrupt is a
        # configuration error and must fail at get_backend() time, not
        # on the first dispatch
        self.table = load_tuning_table(table)
        if self.table is not None and self.table.timer != timer:
            raise ValueError(
                f"tuning table {self.table.path or '<default>'} was tuned "
                f"on timer {self.table.timer!r} but auto asked for "
                f"timer={timer!r}; retune with `benchmarks.run --tune "
                f"--timer {timer}` or point table= at a matching table")
        self._ndev: Optional[int] = None
        self._delegates: Dict[str, Backend] = {}

    # -- resolution (pure lookup, nothing measured) ----------------------
    def _device_count(self) -> int:
        if self._ndev is None:
            import jax

            self._ndev = len(jax.devices())
        return self._ndev

    def resolve_spec(self, graphs: Sequence[TaskGraph]) -> str:
        """The concrete backend spec this workload dispatches to."""
        from ..bench.tuner import graphs_cutout

        if self.table is None:
            return self.fallback
        winner = self.table.resolve(
            graphs_cutout(graphs, ndev=self._device_count()))
        return winner if winner is not None else self.fallback

    def delegate(self, graphs: Sequence[TaskGraph]) -> Backend:
        """The (cached) backend instance the workload resolves to."""
        spec = self.resolve_spec(graphs)
        if spec not in self._delegates:
            self._delegates[spec] = get_backend(spec)
        return self._delegates[spec]

    # -- execution: pure delegation --------------------------------------
    def prepare(self, graphs: Sequence[TaskGraph]
                ) -> Callable[[], List[np.ndarray]]:
        return self.delegate(graphs).prepare(graphs)

    def prepare_many(self, graphs: Sequence[TaskGraph]
                     ) -> Callable[[], List[np.ndarray]]:
        return self.delegate(graphs).prepare_many(graphs)

    def lowered_hlo(self, graphs: Sequence[TaskGraph]) -> List[str]:
        return self.delegate(graphs).lowered_hlo(graphs)
