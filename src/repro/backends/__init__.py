"""Execution backends — the 'n systems' axis of the paper's O(m+n) design.

| backend           | paper analogue                  | schedule        | dispatch cost |
|-------------------|---------------------------------|-----------------|---------------|
| xla-static        | PaRSEC PTG / Regent / TF graph  | unrolled, AOT   | ~0 per task   |
| xla-scan          | OpenMP forall / vectorized      | compiled loop   | O(1) per step |
| shardmap-csp      | MPI CSP (Listing 2)             | SPMD + messages | O(1) per step |
| shardmap-pipeline | pipelined runtime (stage ring)  | SPMD + messages | O(1) per step |
| host-dynamic      | Dask / Spark / Swift-T          | host per task   | O(1) per task |
| pallas-fused      | (below the floor: megakernel)   | in-kernel grid  | O(1) per GRAPH|
| auto              | (planner: no one system wins)   | table-driven    | delegated     |

Every backend runs every graph (pattern x kernel x payload x imbalance)
unchanged, and is validated against the numpy oracle in core.validate.
The two shard_map backends share the ``repro.dist.collectives`` comm-
planning layer (ring/halo/allgather modes, ragged-width padding).
"""
from .base import (Backend, StackedProgramBackend, backend_names,
                   backend_option_signature, canonical_backend_spec,
                   get_backend, parse_backend_spec, register_backend)
from .auto import AutoBackend
from .csp import CSPBackend, PlannedSPMDBackend
from .dataflow import DataflowBackend
from .host import HostBackend
from .megakernel import MegakernelBackend
from .pipeline import PipelineBackend
from .scanvec import ScanBackend

__all__ = [
    "Backend",
    "StackedProgramBackend",
    "backend_names",
    "backend_option_signature",
    "canonical_backend_spec",
    "get_backend",
    "parse_backend_spec",
    "register_backend",
    "AutoBackend",
    "CSPBackend",
    "DataflowBackend",
    "HostBackend",
    "MegakernelBackend",
    "PipelineBackend",
    "PlannedSPMDBackend",
    "ScanBackend",
]
