"""Execution backends — the 'n systems' axis of the paper's O(m+n) design.

| backend       | paper analogue                  | schedule        | dispatch cost |
|---------------|---------------------------------|-----------------|---------------|
| xla-static    | PaRSEC PTG / Regent / TF graph  | unrolled, AOT   | ~0 per task   |
| xla-scan      | OpenMP forall / vectorized      | compiled loop   | O(1) per step |
| shardmap-csp  | MPI CSP (Listing 2)             | SPMD + messages | O(1) per step |
| host-dynamic  | Dask / Spark / Swift-T          | host per task   | O(1) per task |

Every backend runs every graph (pattern x kernel x payload x imbalance)
unchanged, and is validated against the numpy oracle in core.validate.
"""
from .base import Backend, backend_names, get_backend, register_backend
from .csp import CSPBackend
from .dataflow import DataflowBackend
from .host import HostBackend
from .scanvec import ScanBackend

__all__ = [
    "Backend",
    "backend_names",
    "get_backend",
    "register_backend",
    "CSPBackend",
    "DataflowBackend",
    "HostBackend",
    "ScanBackend",
]
