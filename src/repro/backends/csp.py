"""CSP backend: explicit SPMD ranks exchanging messages per timestep.

Analogue of the paper's MPI implementation (Listing 2): columns are
distributed over device ranks via ``shard_map``; every timestep each rank
receives the payloads its local tasks depend on, executes its tasks, and
sends its outputs.  Two communication modes, chosen like an MPI programmer
would:

* ``halo``      — nearest-neighbour ``ppermute`` exchange (stencil/sweep/
                  nearest patterns whose dependency reach fits in a halo).
* ``allgather`` — general fallback for wide patterns (fft/spread/random),
                  the MPI_Allgather of payload rows.

Like MPI CSP, communication and computation strictly alternate — no
overlap, no task parallelism — which is exactly why the paper finds MPI
loses its advantage under imbalance and heavy communication (§V-F/G).
"""
from __future__ import annotations

from functools import partial
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import pcast, shard_map
from ..core.graph import TaskGraph
from . import body
from .base import Backend, register_backend

AXIS = "cols"


def _dependency_reach(graph: TaskGraph) -> int:
    """max |j - i| over all deps — the halo width an MPI rank would post."""
    reach = 0
    for t in range(1, graph.height):
        m = graph.dependence_matrix(t)
        for i, j in np.argwhere(m):
            reach = max(reach, abs(int(j) - int(i)))
    return reach


@register_backend("shardmap-csp")
class CSPBackend(Backend):
    paradigm = "explicit SPMD message passing (MPI CSP analogue)"

    def __init__(self, mesh: Mesh | None = None, comm: str = "auto"):
        if mesh is None:
            devs = np.array(jax.devices())
            mesh = Mesh(devs, (AXIS,))
        if comm not in ("auto", "halo", "allgather"):
            raise ValueError(comm)
        self.mesh = mesh
        self.comm = comm
        self.ndev = mesh.shape[AXIS]

    def _mode(self, graph: TaskGraph, local: int) -> str:
        if self.comm != "auto":
            return self.comm
        reach = _dependency_reach(graph)
        return "halo" if 0 < reach <= local else ("allgather" if reach else "halo")

    def prepare(self, graphs: Sequence[TaskGraph]):
        progs = [self._prepare_one(g) for g in graphs]

        def runner() -> List[np.ndarray]:
            outs = [p() for p in progs]
            return [np.asarray(o) for o in outs]

        return runner

    def _prepare_one(self, graph: TaskGraph):
        W, H, Pels = graph.width, graph.height, graph.payload_elems
        ndev = self.ndev
        if W % ndev:
            raise ValueError(f"width {W} not divisible by {ndev} ranks")
        local = W // ndev
        mode = self._mode(graph, local)
        reach = _dependency_reach(graph) if mode == "halo" else 0
        halo = min(reach, local)

        mats, iters = body.graph_static_inputs(graph)  # (H,W,W), (H,W)
        if mode == "halo":
            # re-index dep columns into [left halo | local | right halo]
            ctx = 2 * halo + local
            lmats = np.zeros((H, W, ctx), dtype=np.uint8)
            for t in range(H):
                for i in range(W):
                    shard, li = divmod(i, local)
                    base = shard * local - halo
                    for j in np.argwhere(mats[t, i]).ravel():
                        lj = int(j) - base
                        assert 0 <= lj < ctx, (t, i, j, lj)
                        lmats[t, i, lj] = 1
        else:
            lmats = mats  # context is the full gathered width

        lmats_j = jnp.asarray(lmats)
        iters_j = jnp.asarray(iters)
        dynamic = local == 1  # true per-rank loops can stop early

        def rank_program(lmats_l, iters_l):
            """Runs on one rank: lmats_l (H, local, ctx), iters_l (H, local)."""
            rank = jax.lax.axis_index(AXIS)
            cols = rank * local + jnp.arange(local)
            payload0 = jnp.zeros((local, Pels), jnp.float32)
            # the carry becomes device-varying after the first exchange;
            # mark it so from the start (shard_map vma typing)
            payload0 = pcast(payload0, (AXIS,), to="varying")

            def step(payload, xs):
                t, mat_t, it_t = xs
                if mode == "halo":
                    if halo > 0:
                        right_dst = [(r, r + 1) for r in range(ndev - 1)]
                        left_dst = [(r, r - 1) for r in range(1, ndev)]
                        from_left = jax.lax.ppermute(
                            payload[-halo:], AXIS, right_dst) if right_dst else \
                            jnp.zeros((halo, Pels), jnp.float32)
                        from_right = jax.lax.ppermute(
                            payload[:halo], AXIS, left_dst) if left_dst else \
                            jnp.zeros((halo, Pels), jnp.float32)
                        ctx_payload = jnp.concatenate(
                            [from_left, payload, from_right])
                    else:
                        ctx_payload = payload
                else:
                    ctx_payload = jax.lax.all_gather(payload, AXIS, tiled=True)
                new = body.timestep(graph, t, ctx_payload, mat_t, it_t,
                                    cols=cols, dynamic=dynamic)
                return new, None

            ts = jnp.arange(H, dtype=jnp.uint32)
            final, _ = jax.lax.scan(step, payload0, (ts, lmats_l, iters_l))
            return final

        shmapped = shard_map(
            rank_program,
            mesh=self.mesh,
            in_specs=(P(None, AXIS, None), P(None, AXIS)),
            out_specs=P(AXIS, None),
        )
        fn = jax.jit(shmapped)
        compiled = fn.lower(lmats_j, iters_j).compile()

        def run_one():
            return jax.block_until_ready(compiled(lmats_j, iters_j))

        return run_one
