"""CSP backend: explicit SPMD ranks exchanging messages per timestep.

Analogue of the paper's MPI implementation (Listing 2): columns are
distributed over device ranks via ``shard_map``; every timestep each rank
receives the payloads its local tasks depend on, executes its tasks, and
sends its outputs.  All planning — halo sizing, ragged-width padding,
dependence re-indexing, mode selection — lives in
``repro.dist.collectives.CommPlan``; this module only owns execution.

``PlannedSPMDBackend`` is the shared rank-program machinery: any backend
that blocks graph columns over a mesh axis and moves payloads with a
``CommPlan`` (CSP over ``cols``, the pipeline backend over ``stage``)
subclasses it and picks an axis + mode preference.

Like MPI CSP, communication and computation strictly alternate by
default — no overlap, no task parallelism — which is exactly why the
paper finds MPI loses its advantage under imbalance and heavy
communication (§V-F/G).  ``comm_overlap=True`` switches both the
single-graph and the combined multi-graph programs to the
double-buffered form (the MPI_Isend/Irecv analogue): the scan carry
holds the *pre-exchanged* context for the current timestep, and each
step issues the next timestep's exchange immediately after producing its
payload — ahead of the next kernel body — so XLA's async collectives may
run while compute proceeds.  The final timestep runs outside the scan
(its payload needs no exchange), so both forms issue exactly H
exchanges, and the exchanged values are identical — conformance is
bit-exact either way.

``comm="onesided"`` drops the rendezvous entirely (the NVSHMEM-style
put/signal idiom): the scan carry holds the plan's receive buffers and
signal counters (``CommPlan.onesided_state``), each step's producers
push their dependency rows and raise the consumer's flag
(``onesided_push``), and consumers assemble their context through the
masked ``signal_wait_until`` (``onesided_wait``) instead of joining a
collective.  Composes with ``comm_overlap`` (the wait for step t+1 is
issued right after step t's push, ahead of the next kernel body) and
with the combined multi-graph program; bit-exact with every other mode.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import pcast, shard_map
from ..core.graph import TaskGraph
from ..dist import collectives as CC
from . import body
from .base import Backend, register_backend

AXIS = "cols"


class PlannedSPMDBackend(Backend):
    """Columns blocked over one mesh axis; movement per a ``CommPlan``.

    Ragged widths are handled by the plan's dead-column padding, so any
    graph width runs on any rank count (including width < ndev).
    """

    axis = AXIS
    prefer_ring = False

    def __init__(self, mesh: Mesh | None = None, comm: str = "auto",
                 comm_overlap: bool = False):
        if mesh is None:
            devs = np.array(jax.devices())
            mesh = Mesh(devs, (self.axis,))
        if comm not in CC.MODES:
            raise ValueError(f"unknown comm mode {comm!r}; known: {CC.MODES}")
        self.mesh = mesh
        self.comm = comm
        self.comm_overlap = bool(comm_overlap)
        self.ndev = mesh.shape[self.axis]

    def plan(self, graph: TaskGraph) -> CC.CommPlan:
        return CC.plan_comm(graph, self.ndev, self.axis, comm=self.comm,
                            prefer_ring=self.prefer_ring,
                            comm_overlap=self.comm_overlap)

    def prepare(self, graphs: Sequence[TaskGraph]):
        progs = [self._prepare_one(g) for g in graphs]

        def runner() -> List[np.ndarray]:
            outs = [p() for p in progs]
            return [np.asarray(o) for o in outs]

        return runner

    def _compile_one(self, graph: TaskGraph):
        plan = self.plan(graph)
        local, Pels = plan.local, graph.payload_elems
        lmats_j = jnp.asarray(plan.local_mats)
        iters_j = jnp.asarray(plan.iters)
        dynamic = local == 1  # true per-rank loops can stop early

        def rank_program(lmats_l, iters_l):
            """Runs on one rank: lmats_l (H, local, ctx), iters_l (H, local)."""
            cols = plan.local_cols()
            payload0 = jnp.zeros((local, Pels), jnp.float32)
            # the carry becomes device-varying after the first exchange;
            # mark it so from the start (shard_map vma typing)
            payload0 = pcast(payload0, (self.axis,), to="varying")
            ts = jnp.arange(graph.height, dtype=jnp.uint32)

            if plan.mode == "onesided":
                recv0, sig0 = plan.onesided_state(Pels)
                recv0 = pcast(recv0, (self.axis,), to="varying")
                sig0 = pcast(sig0, (self.axis,), to="varying")

                if plan.comm_overlap:
                    # put/signal double buffering: step t pushes its
                    # payload, then immediately issues step t+1's masked
                    # wait — ahead of the next kernel body
                    def step(carry, xs):
                        ctx, recv, sig = carry
                        t, mat_t, it_t = xs
                        new = body.timestep(graph, t, ctx, mat_t, it_t,
                                            cols=cols, dynamic=dynamic)
                        recv, sig = plan.onesided_push(new, recv, sig)
                        ctx = plan.onesided_wait(recv, sig, t + 1, new)
                        return (ctx, recv, sig), None

                    ctx0 = plan.onesided_wait(recv0, sig0, 0, payload0)
                    (ctx, _, _), _ = jax.lax.scan(
                        step, (ctx0, recv0, sig0),
                        (ts[:-1], lmats_l[:-1], iters_l[:-1]))
                    return body.timestep(graph, ts[-1], ctx, lmats_l[-1],
                                         iters_l[-1], cols=cols,
                                         dynamic=dynamic)

                def step(carry, xs):
                    payload, recv, sig = carry
                    t, mat_t, it_t = xs
                    ctx = plan.onesided_wait(recv, sig, t, payload)
                    new = body.timestep(graph, t, ctx, mat_t, it_t,
                                        cols=cols, dynamic=dynamic)
                    recv, sig = plan.onesided_push(new, recv, sig)
                    return (new, recv, sig), None

                (final, _, _), _ = jax.lax.scan(
                    step, (payload0, recv0, sig0), (ts, lmats_l, iters_l))
                return final

            if plan.comm_overlap:
                # double-buffered: the carry holds this step's already-
                # exchanged context; each step issues the *next* step's
                # exchange ahead of the next kernel body.  The last
                # timestep runs outside the scan — its payload needs no
                # further exchange, so the program issues exactly H
                # exchanges, the same count as the blocking form
                def step(ctx_payload, xs):
                    t, mat_t, it_t = xs
                    new = body.timestep(graph, t, ctx_payload, mat_t, it_t,
                                        cols=cols, dynamic=dynamic)
                    return plan.exchange(new), None

                ctx, _ = jax.lax.scan(
                    step, plan.exchange(payload0),
                    (ts[:-1], lmats_l[:-1], iters_l[:-1]))
                return body.timestep(graph, ts[-1], ctx, lmats_l[-1],
                                     iters_l[-1], cols=cols, dynamic=dynamic)

            def step(payload, xs):
                t, mat_t, it_t = xs
                ctx_payload = plan.exchange(payload)
                new = body.timestep(graph, t, ctx_payload, mat_t, it_t,
                                    cols=cols, dynamic=dynamic)
                return new, None

            final, _ = jax.lax.scan(step, payload0, (ts, lmats_l, iters_l))
            return final

        shmapped = shard_map(
            rank_program,
            mesh=self.mesh,
            in_specs=(P(None, self.axis, None), P(None, self.axis)),
            out_specs=P(self.axis, None),
            # dynamic mode lowers the kernel loop to `while` (traced trip
            # count), which the legacy check_rep pass cannot type
            check_vma=not dynamic,
        )
        fn = jax.jit(shmapped)
        compiled = fn.lower(lmats_j, iters_j).compile()
        return compiled, plan, lmats_j, iters_j

    def _prepare_one(self, graph: TaskGraph):
        compiled, plan, lmats_j, iters_j = self._compile_one(graph)

        def run_one():
            out = jax.block_until_ready(compiled(lmats_j, iters_j))
            return plan.trim(out)

        return run_one

    def _compile_combined(self, graphs: Sequence[TaskGraph]):
        """One shard_map program interleaving every graph's wavefront.

        Each scan step exchanges and executes timestep ``t`` of *all*
        graphs, so XLA may overlap one graph's ppermute/all_gather with
        another's kernels — the rank-parallel form of task parallelism.
        Requires a common height (the shared clock); None otherwise.
        """
        if len(graphs) < 2 or len({g.height for g in graphs}) != 1:
            return None
        plans = [self.plan(g) for g in graphs]
        height = graphs[0].height
        dynamics = [p.local == 1 for p in plans]
        lmats = tuple(jnp.asarray(p.local_mats) for p in plans)
        iters = tuple(jnp.asarray(p.iters) for p in plans)

        def rank_program(lmats_l, iters_l):
            colss = tuple(p.local_cols() for p in plans)
            payloads = tuple(
                pcast(jnp.zeros((p.local, g.payload_elems), jnp.float32),
                      (self.axis,), to="varying")
                for p, g in zip(plans, graphs))
            ts = jnp.arange(height, dtype=jnp.uint32)

            if self.comm == "onesided":
                # every graph's (recv, sig) rides the shared carry; each
                # step pushes/waits all graphs, so one graph's puts may
                # overlap another's kernels like the collective forms
                states = tuple(
                    tuple(pcast(s, (self.axis,), to="varying")
                          for s in p.onesided_state(g.payload_elems))
                    for p, g in zip(plans, graphs))

                if self.comm_overlap:
                    def step(carry, xs):
                        t, mats_t, its_t = xs
                        out = []
                        for g, p, (ctx, recv, sig), m, it, cols, dyn in zip(
                                graphs, plans, carry, mats_t, its_t,
                                colss, dynamics):
                            new = body.timestep(g, t, ctx, m, it,
                                                cols=cols, dynamic=dyn)
                            recv, sig = p.onesided_push(new, recv, sig)
                            ctx = p.onesided_wait(recv, sig, t + 1, new)
                            out.append((ctx, recv, sig))
                        return tuple(out), None

                    init = tuple(
                        (p.onesided_wait(recv, sig, 0, c), recv, sig)
                        for p, c, (recv, sig) in zip(plans, payloads, states))
                    carry, _ = jax.lax.scan(
                        step, init,
                        (ts[:-1], tuple(m[:-1] for m in lmats_l),
                         tuple(i[:-1] for i in iters_l)))
                    return tuple(
                        body.timestep(g, ts[-1], ctx, m[-1], it[-1],
                                      cols=cols, dynamic=dyn)
                        for g, (ctx, _, _), m, it, cols, dyn in zip(
                            graphs, carry, lmats_l, iters_l, colss,
                            dynamics))

                def step(carry, xs):
                    t, mats_t, its_t = xs
                    out = []
                    for g, p, (payload, recv, sig), m, it, cols, dyn in zip(
                            graphs, plans, carry, mats_t, its_t,
                            colss, dynamics):
                        ctx = p.onesided_wait(recv, sig, t, payload)
                        new = body.timestep(g, t, ctx, m, it,
                                            cols=cols, dynamic=dyn)
                        recv, sig = p.onesided_push(new, recv, sig)
                        out.append((new, recv, sig))
                    return tuple(out), None

                init = tuple((c,) + s for c, s in zip(payloads, states))
                carry, _ = jax.lax.scan(step, init, (ts, lmats_l, iters_l))
                return tuple(payload for payload, _, _ in carry)

            if self.comm_overlap:
                # as in _compile_one: the last tick runs outside the scan
                # so every pipeline issues exactly H exchanges
                def step(ctxs, xs):
                    t, mats_t, its_t = xs
                    new = tuple(
                        body.timestep(g, t, ctx, m, it,
                                      cols=cols, dynamic=dyn)
                        for g, ctx, m, it, cols, dyn in zip(
                            graphs, ctxs, mats_t, its_t, colss, dynamics))
                    return tuple(p.exchange(n)
                                 for p, n in zip(plans, new)), None

                ctxs, _ = jax.lax.scan(
                    step,
                    tuple(p.exchange(c) for p, c in zip(plans, payloads)),
                    (ts[:-1], tuple(m[:-1] for m in lmats_l),
                     tuple(i[:-1] for i in iters_l)))
                return tuple(
                    body.timestep(g, ts[-1], ctx, m[-1], it[-1],
                                  cols=cols, dynamic=dyn)
                    for g, ctx, m, it, cols, dyn in zip(
                        graphs, ctxs, lmats_l, iters_l, colss, dynamics))

            def step(carry, xs):
                t, mats_t, its_t = xs
                new = tuple(
                    body.timestep(g, t, p.exchange(c), m, it,
                                  cols=cols, dynamic=dyn)
                    for g, p, c, m, it, cols, dyn in zip(
                        graphs, plans, carry, mats_t, its_t, colss, dynamics))
                return new, None

            final, _ = jax.lax.scan(step, payloads, (ts, lmats_l, iters_l))
            return final

        shmapped = shard_map(
            rank_program,
            mesh=self.mesh,
            in_specs=(tuple(P(None, self.axis, None) for _ in plans),
                      tuple(P(None, self.axis) for _ in plans)),
            out_specs=tuple(P(self.axis, None) for _ in plans),
            check_vma=not any(dynamics),
        )
        compiled = jax.jit(shmapped).lower(lmats, iters).compile()
        return compiled, plans, lmats, iters

    def prepare_many(self, graphs: Sequence[TaskGraph]):
        graphs = list(graphs)
        built = self._compile_combined(graphs)
        if built is None:
            return self.prepare(graphs)
        compiled, plans, lmats, iters = built

        def runner() -> List[np.ndarray]:
            outs = jax.block_until_ready(compiled(lmats, iters))
            return [np.asarray(p.trim(o)) for p, o in zip(plans, outs)]

        return runner

    def lowered_hlo(self, graphs: Sequence[TaskGraph]) -> List[str]:
        graphs = list(graphs)
        built = self._compile_combined(graphs)
        if built is not None:
            return [built[0].as_text()]
        return [self._compile_one(g)[0].as_text() for g in graphs]


@register_backend("shardmap-csp")
class CSPBackend(PlannedSPMDBackend):
    paradigm = "explicit SPMD message passing (MPI CSP analogue)"
