"""Pipeline backend: stages sharded over a ``stage`` mesh axis.

A pipeline schedule IS a sweep task graph (``dist.pipeline.pp_schedule``):
column = stage, timestep = clock tick, and the only cross-column
dependence reaches *left* — the activation arriving from the previous
stage.  This backend executes any such graph with one column block per
rank of a ``stage`` mesh axis and the activation moved stage-to-stage by
a one-directional ``ppermute`` ring (``CommPlan`` mode ``ring``) — the
point-to-point send a pipelined runtime would issue, with no reverse
link and no gather.

Because the comm-planning layer is shared, the backend is not limited to
sweeps: graphs whose deps also reach right fall back to the plan's
``halo`` exchange, and wide patterns (fft/spread/random) to
``allgather`` — so the backend joins the full benchmark matrix
(every pattern x every backend) unmodified.  Multi-graph scenarios
(``run_many``) inherit ``PlannedSPMDBackend``'s combined program: every
pipeline advances one clock tick per scan step, rings interleaved.

``comm_overlap=True`` (inherited from ``PlannedSPMDBackend``) switches
to the double-buffered program: the activation ring transfer for clock
tick t+1 is issued right after tick t's stage body — the async
stage-to-stage send a pipelined runtime posts while the next microbatch
computes.
"""
from __future__ import annotations

from .base import register_backend
from .csp import PlannedSPMDBackend

AXIS = "stage"


@register_backend("shardmap-pipeline")
class PipelineBackend(PlannedSPMDBackend):
    paradigm = "pipeline stages over a mesh axis (ppermute ring)"
    axis = AXIS
    prefer_ring = True
