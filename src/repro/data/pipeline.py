"""Deterministic synthetic LM data pipeline.

Production shape without production data: a seeded, host-side token stream
(Philox counter-based — O(1) random access by (seed, step, shard)) with a
zipf-ish unigram distribution plus local n-gram structure so losses are
learnable (models can reduce loss against it in the examples).  Sharded by
data-parallel host rank, background-prefetched, and restart-deterministic:
batch(step) is a pure function, so resuming from a checkpoint replays the
exact stream — the fault-tolerance test relies on this.

For modality-frontend archs (vlm/audio) the stream emits precomputed
frame/patch embeddings per the assignment's stub contract.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic structure: tokens repeat with lag `ngram_lag` w.p. `ngram_p`
    ngram_p: float = 0.5
    ngram_lag: int = 2
    # modality stub
    embed_dim: int = 0  # >0 -> emit embeddings instead of tokens
    host_id: int = 0
    num_hosts: int = 1


def _rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    key = (cfg.seed << 96) | (step << 32) | (shard << 8) | 0xD5
    return np.random.Generator(np.random.Philox(key=key))


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function (cfg, step) -> host-local batch."""
    assert cfg.global_batch % cfg.num_hosts == 0
    local = cfg.global_batch // cfg.num_hosts
    rng = _rng(cfg, step, cfg.host_id)
    if cfg.embed_dim:
        emb = rng.standard_normal(
            (local, cfg.seq_len, cfg.embed_dim), dtype=np.float32)
        labels = rng.integers(0, cfg.vocab_size,
                              (local, cfg.seq_len), dtype=np.int32)
        return {"embeds": emb, "labels": labels}
    # zipf-ish unigram over vocab with n-gram copy structure
    raw = rng.zipf(1.3, size=(local, cfg.seq_len + 1)).astype(np.int64)
    toks = (raw % (cfg.vocab_size - 1)) + 1  # reserve 0 as BOS
    copy = rng.random((local, cfg.seq_len + 1)) < cfg.ngram_p
    lag = cfg.ngram_lag
    toks[:, lag:] = np.where(copy[:, lag:], toks[:, :-lag], toks[:, lag:])
    toks[:, 0] = 0
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background-thread prefetch of make_batch(step) results."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
