"""JAX version compatibility shims.

The repo targets the modern ``jax.shard_map`` API (keyword ``mesh`` /
``in_specs`` / ``out_specs``, ``check_vma``), but must also run on
JAX 0.4.x where ``shard_map`` lives in ``jax.experimental.shard_map``
(with the replication check spelled ``check_rep``) and ``jax.lax.pcast``
does not exist.  All code under ``src/`` imports these names from here
instead of touching ``jax.shard_map`` / ``jax.lax.pcast`` directly
(enforced by ``tests/test_compat.py``).
"""
from __future__ import annotations

import jax

_native_shard_map = getattr(jax, "shard_map", None)
if _native_shard_map is None:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
else:
    _legacy_shard_map = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
    """``jax.shard_map`` on new JAX; the experimental one on 0.4.x.

    ``check_vma`` maps onto the legacy ``check_rep`` flag — both gate the
    same replication/varying-manual-axes validation.
    """
    if _native_shard_map is not None:
        return _native_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kwargs)
    return _legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, **kwargs)


def pcast(x, axis_names, to="varying"):
    """``jax.lax.pcast`` where available; identity otherwise.

    Old shard_map has no varying-manual-axes typing, so there is nothing
    to cast — values become device-varying implicitly.
    """
    fn = getattr(jax.lax, "pcast", None)
    if fn is None:
        return x
    return fn(x, axis_names, to=to)
