"""Unified communication planning for SPMD backends (the comm-plan layer).

Both rank-parallel backends (``shardmap-csp``, ``shardmap-pipeline``) and
the distributed training stack move dependency payloads between device
ranks each timestep.  This module lifts that planning out of the backends
into one reusable object, ``CommPlan``:

* **analysis** — ``dependency_reach``/``directional_reach`` vectorize the
  dependence-offset scan over ``TaskGraph.dependence_matrices()`` (one
  ``np.nonzero`` over the whole stack instead of a Python loop per
  timestep) and short-circuit to a single timestep slice for
  time-invariant graphs;
* **placement** — columns are blocked over ``ndev`` ranks, padding ragged
  widths up to the next multiple with *dead columns* (zero dependence
  rows, zero iterations) so any width runs on any rank count — the
  paper's MPI implementation handles ragged columns the same way;
* **movement** — three modes, selected automatically from the reach:

  ====================  =====================================================
  ``ring``              one-directional ``ppermute`` toward higher ranks —
                        the pipeline stage-to-stage activation transfer
                        (deps reach left only, e.g. sweep graphs)
  ``halo``              bidirectional nearest-neighbour ``ppermute``
                        exchange (stencil/nearest reach fits in a halo)
  ``allgather``         full payload-row gather — the MPI_Allgather
                        fallback for wide patterns (fft/spread/random)
  ====================  =====================================================

``CommPlan.exchange`` executes the planned movement *inside* ``shard_map``;
``CommPlan.local_mats`` are the dependence matrices re-indexed into each
rank's context window ``[left halo | local block | right halo]``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import TaskGraph

MODES = ("auto", "ring", "halo", "allgather")


def _dep_offsets(graph: TaskGraph) -> np.ndarray:
    """All distinct dependence offsets ``j - i`` across the graph.

    Vectorized: one ``np.nonzero`` over the stacked matrices; graphs whose
    dependence relation is time-invariant are analyzed from a single
    timestep slice instead of the full (H, W, W) stack.
    """
    if graph.height <= 1:
        return np.empty((0,), np.int64)
    if graph.is_time_invariant():
        mats = graph.dependence_matrix(1)[None]
    else:
        mats = graph.dependence_matrices()[1:]
    _, i, j = np.nonzero(mats)
    return np.unique(j.astype(np.int64) - i.astype(np.int64))


def directional_reach(graph: TaskGraph) -> Tuple[int, int]:
    """(left, right): how far deps reach toward lower / higher columns."""
    offs = _dep_offsets(graph)
    if offs.size == 0:
        return 0, 0
    return int(max(-offs.min(), 0)), int(max(offs.max(), 0))


def dependency_reach(graph: TaskGraph) -> int:
    """max |j - i| over all deps — the halo width an MPI rank would post."""
    left, right = directional_reach(graph)
    return max(left, right)


# eq=False: ndarray fields would make the generated __eq__/__hash__ raise
@dataclasses.dataclass(frozen=True, eq=False)
class CommPlan:
    """How one graph's payloads are laid out and moved over ``ndev`` ranks.

    ``local_mats``/``iters`` are padded to ``padded_width`` columns; dead
    columns (>= ``width``) have empty dependence rows and zero iterations,
    and are sliced away by ``trim``.
    """

    mode: str            # "ring" | "halo" | "allgather"
    axis: str            # mesh axis name the ranks live on
    ndev: int
    width: int           # real graph width
    padded_width: int    # next multiple of ndev
    local: int           # columns per rank
    halo: int            # exchange width (0 => no communication)
    local_mats: np.ndarray   # (H, padded_width, ctx) uint8
    iters: np.ndarray        # (H, padded_width) int32

    @property
    def ragged(self) -> bool:
        return self.padded_width != self.width

    @property
    def context_width(self) -> int:
        """Columns of t-1 payload visible to each rank after exchange."""
        return self.local_mats.shape[-1]

    def local_cols(self):
        """Global column ids of the calling rank (inside ``shard_map``)."""
        rank = jax.lax.axis_index(self.axis)
        return rank * self.local + jnp.arange(self.local)

    def exchange(self, payload):
        """Move t-1 payloads into this rank's context (inside ``shard_map``).

        payload: (local, P) f32 — the rank's own previous-timestep rows.
        Returns (context_width, P) rows ordered to match ``local_mats``.
        """
        if self.mode == "allgather":
            return jax.lax.all_gather(payload, self.axis, tiled=True)
        if self.halo == 0:
            return payload
        h, P = self.halo, payload.shape[-1]
        zeros = jnp.zeros((h, P), payload.dtype)
        fwd = [(r, r + 1) for r in range(self.ndev - 1)]
        from_left = (jax.lax.ppermute(payload[-h:], self.axis, fwd)
                     if fwd else zeros)
        if self.mode == "ring":
            return jnp.concatenate([from_left, payload])
        bwd = [(r, r - 1) for r in range(1, self.ndev)]
        from_right = (jax.lax.ppermute(payload[:h], self.axis, bwd)
                      if bwd else zeros)
        return jnp.concatenate([from_left, payload, from_right])

    def trim(self, gathered):
        """Drop dead padding columns from a (padded_width, ...) output."""
        return gathered[: self.width]


def _padded_static_inputs(graph: TaskGraph, padded: int):
    """Dep matrices (H, padded, padded) u8 + iteration counts (H, padded)."""
    from ..backends import body  # local import: backends import this module

    mats, iters = body.graph_static_inputs(graph)
    W = graph.width
    if padded == W:
        return mats, iters
    H = graph.height
    pm = np.zeros((H, padded, padded), np.uint8)
    pm[:, :W, :W] = mats
    pi = np.zeros((H, padded), np.int32)  # dead columns: no work
    pi[:, :W] = iters
    return pm, pi


def plan_comm(
    graph: TaskGraph,
    ndev: int,
    axis: str,
    comm: str = "auto",
    prefer_ring: bool = False,
) -> CommPlan:
    """Build the communication plan for ``graph`` over ``ndev`` ranks.

    ``comm`` forces a mode; ``auto`` picks the cheapest legal one.  With
    ``prefer_ring`` (pipeline backends), graphs whose deps reach only
    toward lower columns use the one-directional ring instead of the
    bidirectional halo.
    """
    if comm not in MODES:
        raise ValueError(f"unknown comm mode {comm!r}; known: {MODES}")
    if ndev < 1:
        raise ValueError(f"need at least one rank, got {ndev}")
    W, H = graph.width, graph.height
    padded = -(-W // ndev) * ndev
    local = padded // ndev
    left, right = directional_reach(graph)
    reach = max(left, right)

    if comm == "auto":
        if reach > local:
            mode = "allgather"
        elif prefer_ring and right == 0:
            mode = "ring"
        else:
            mode = "halo"
    else:
        mode = comm
        if mode == "ring" and right > 0:
            raise ValueError(
                f"ring comm needs left-only deps, but reach is "
                f"(left={left}, right={right})")
        if mode in ("ring", "halo") and reach > local:
            raise ValueError(
                f"{mode} comm cannot cover reach {reach} with "
                f"{local} columns per rank; use allgather")

    mats, iters = _padded_static_inputs(graph, padded)
    if mode == "allgather":
        halo = 0
        lmats = mats  # context is the full gathered (padded) width
    else:
        halo = min(reach if mode == "halo" else left, local)
        lhalo, rhalo = halo, (halo if mode == "halo" else 0)
        ctx = lhalo + local + rhalo
        lmats = np.zeros((H, padded, ctx), np.uint8)
        t_idx, i_idx, j_idx = np.nonzero(mats)
        # re-index dep columns into [left halo | local block | right halo]
        lj = j_idx - ((i_idx // local) * local - lhalo)
        assert ((0 <= lj) & (lj < ctx)).all(), (mode, halo, local)
        lmats[t_idx, i_idx, lj] = 1

    return CommPlan(
        mode=mode, axis=axis, ndev=ndev, width=W, padded_width=padded,
        local=local, halo=halo, local_mats=lmats, iters=iters,
    )
