"""Unified communication planning for SPMD backends (the comm-plan layer).

Both rank-parallel backends (``shardmap-csp``, ``shardmap-pipeline``) and
the distributed training stack move dependency payloads between device
ranks each timestep.  This module lifts that planning out of the backends
into one reusable object, ``CommPlan``:

* **analysis** — ``dependency_reach``/``directional_reach`` vectorize the
  dependence-offset scan over ``TaskGraph.dependence_matrices()`` (one
  ``np.nonzero`` over the whole stack instead of a Python loop per
  timestep) and short-circuit to a single timestep slice for
  time-invariant graphs;
* **placement** — columns are blocked over ``ndev`` ranks, padding ragged
  widths up to the next multiple with *dead columns* (zero dependence
  rows, zero iterations) so any width runs on any rank count — the
  paper's MPI implementation handles ragged columns the same way;
* **movement** — four modes, the first three selected automatically from
  the reach:

  ====================  =====================================================
  ``ring``              one-directional ``ppermute`` toward higher ranks —
                        the pipeline stage-to-stage activation transfer
                        (deps reach left only, e.g. sweep graphs)
  ``halo``              bidirectional nearest-neighbour ``ppermute``
                        exchange (stencil/nearest reach fits in a halo)
  ``allgather``         full payload-row gather — the MPI_Allgather
                        fallback for wide patterns (fft/spread/random)
  ``a2a``               per-pair ``all_to_all``: each rank sends every other
                        rank exactly the payload rows that rank's columns
                        depend on (MPI_Alltoallv analogue); send/recv counts
                        form a permutation — tokens are conserved
  ``onesided``          NVSHMEM-style put/signal: producers *push* their
                        dependency rows straight into per-consumer receive
                        buffers and raise a signal flag; consumers spin on a
                        ``signal_wait_until`` mask instead of joining a
                        rendezvous.  Same per-pair slot layout as ``a2a``,
                        but the receive buffers and signal counters persist
                        across timesteps (scan state), so there is no
                        collective barrier per step — the portable emulation
                        moves each packet with a point-to-point ``ppermute``
                        and carries the signal with the payload
  ====================  =====================================================

``CommPlan.exchange`` executes the planned movement *inside* ``shard_map``;
``CommPlan.local_mats`` are the dependence matrices re-indexed into each
rank's context window (``[left halo | local block | right halo]`` for the
ppermute modes, ``[recv buffers | local block]`` for ``a2a``/``onesided``).
For ``onesided`` the stateful form is primary: ``onesided_state`` builds
the (receive buffers, signals) pair the executing scan carries,
``onesided_push`` is the producer's put+signal, ``onesided_wait`` the
consumer's masked ``signal_wait_until`` + context assembly.

This module also owns the *dynamic* token all-to-all used by MoE expert
parallelism (``TokenA2APlan``): the same dispatch planning — capacity
sizing, slotting, per-destination buffers, forward/reverse ``all_to_all``
— with the destination of each row decided at runtime by the router
instead of statically by the dependence matrices.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import TaskGraph

MODES = ("auto", "ring", "halo", "allgather", "a2a", "onesided")


def _dep_offsets(graph: TaskGraph) -> np.ndarray:
    """All distinct dependence offsets ``j - i`` across the graph.

    Vectorized: one ``np.nonzero`` over the stacked matrices; graphs whose
    dependence relation is time-invariant are analyzed from a single
    timestep slice instead of the full (H, W, W) stack.
    """
    if graph.height <= 1:
        return np.empty((0,), np.int64)
    if graph.is_time_invariant():
        mats = graph.dependence_matrix(1)[None]
    else:
        mats = graph.dependence_matrices()[1:]
    _, i, j = np.nonzero(mats)
    return np.unique(j.astype(np.int64) - i.astype(np.int64))


def directional_reach(graph: TaskGraph) -> Tuple[int, int]:
    """(left, right): how far deps reach toward lower / higher columns."""
    offs = _dep_offsets(graph)
    if offs.size == 0:
        return 0, 0
    return int(max(-offs.min(), 0)), int(max(offs.max(), 0))


def dependency_reach(graph: TaskGraph) -> int:
    """max |j - i| over all deps — the halo width an MPI rank would post."""
    left, right = directional_reach(graph)
    return max(left, right)


# eq=False: ndarray fields would make the generated __eq__/__hash__ raise
@dataclasses.dataclass(frozen=True, eq=False)
class CommPlan:
    """How one graph's payloads are laid out and moved over ``ndev`` ranks.

    ``local_mats``/``iters`` are padded to ``padded_width`` columns; dead
    columns (>= ``width``) have empty dependence rows and zero iterations,
    and are sliced away by ``trim``.
    """

    mode: str            # "ring" | "halo" | "allgather" | "a2a" | "onesided"
    axis: str            # mesh axis name the ranks live on
    ndev: int
    width: int           # real graph width
    padded_width: int    # next multiple of ndev
    local: int           # columns per rank
    halo: int            # exchange width (0 => no communication)
    local_mats: np.ndarray   # (H, padded_width, ctx) uint8
    iters: np.ndarray        # (H, padded_width) int32
    # double-buffered communication: the executing program issues timestep
    # t+1's exchange right after timestep t's payload is produced (ahead
    # of t+1's kernel body), so XLA's async collectives may overlap with
    # compute.  Pure program-shape flag: ``exchange`` itself is identical.
    comm_overlap: bool = False
    # a2a/onesided modes: [src, dst] row counts and padded send-row indices
    send_counts: Optional[np.ndarray] = None   # (ndev, ndev) int64
    a2a_cap: int = 0                           # rows per (src, dst) buffer
    a2a_send_idx: Optional[np.ndarray] = None  # (ndev, ndev, cap) int32

    @property
    def ragged(self) -> bool:
        return self.padded_width != self.width

    @property
    def recv_counts(self) -> Optional[np.ndarray]:
        """[dst, src] rows received — the transpose of ``send_counts``:
        every row sent is received exactly once (token conservation)."""
        return None if self.send_counts is None else self.send_counts.T

    @property
    def context_width(self) -> int:
        """Columns of t-1 payload visible to each rank after exchange."""
        return self.local_mats.shape[-1]

    def local_cols(self):
        """Global column ids of the calling rank (inside ``shard_map``)."""
        rank = jax.lax.axis_index(self.axis)
        return rank * self.local + jnp.arange(self.local)

    def exchange(self, payload):
        """Move t-1 payloads into this rank's context (inside ``shard_map``).

        payload: (local, P) f32 — the rank's own previous-timestep rows.
        Returns (context_width, P) rows ordered to match ``local_mats``.
        """
        if self.mode == "allgather":
            return jax.lax.all_gather(payload, self.axis, tiled=True)
        if self.mode == "onesided":
            # stateless fallback (one-shot put + immediate wait); the
            # executing backends carry (recv, sig) across steps instead
            recv, sig = self.onesided_state(payload.shape[-1], payload.dtype)
            recv, sig = self.onesided_push(payload, recv, sig)
            return self.onesided_wait(recv, sig, 1, payload)
        if self.mode == "a2a":
            if self.a2a_cap == 0:
                return payload  # no remote deps: context is the local block
            rank = jax.lax.axis_index(self.axis)
            idx = jnp.take(jnp.asarray(self.a2a_send_idx), rank, axis=0)
            send = jnp.take(payload, idx, axis=0)      # (ndev, cap, P)
            recv = jax.lax.all_to_all(send, self.axis, 0, 0)
            return jnp.concatenate(
                [recv.reshape(self.ndev * self.a2a_cap, -1), payload])
        if self.halo == 0:
            return payload
        h, P = self.halo, payload.shape[-1]
        zeros = jnp.zeros((h, P), payload.dtype)
        fwd = [(r, r + 1) for r in range(self.ndev - 1)]
        from_left = (jax.lax.ppermute(payload[-h:], self.axis, fwd)
                     if fwd else zeros)
        if self.mode == "ring":
            return jnp.concatenate([from_left, payload])
        bwd = [(r, r - 1) for r in range(1, self.ndev)]
        from_right = (jax.lax.ppermute(payload[:h], self.axis, bwd)
                      if bwd else zeros)
        return jnp.concatenate([from_left, payload, from_right])

    def trim(self, gathered):
        """Drop dead padding columns from a (padded_width, ...) output."""
        return gathered[: self.width]

    # ------------------------------------------ onesided put/signal mode
    @functools.cached_property
    def _onesided_offsets(self) -> List[Tuple[int, np.ndarray, np.ndarray]]:
        """Static transport schedule: one entry per *active* ring offset.

        ``(offset, idx_table, flag_table)``: rank ``r`` puts the payload
        rows ``idx_table[r]`` to rank ``(r + offset) % ndev`` and raises
        the consumer's signal iff ``flag_table[r]`` (the pair is live).
        Every rank executes every offset's put — the SPMD-uniform
        structure one-sided hardware paths (remote DMA) require — and
        dead pairs deliver masked garbage no ``local_mats`` entry reads.
        """
        assert self.mode == "onesided" and self.send_counts is not None
        out: List[Tuple[int, np.ndarray, np.ndarray]] = []
        for off in range(1, self.ndev):
            dsts = (np.arange(self.ndev) + off) % self.ndev
            live = self.send_counts[np.arange(self.ndev), dsts] > 0
            if not live.any():
                continue
            idx = self.a2a_send_idx[np.arange(self.ndev), dsts]  # (ndev, cap)
            out.append((off, idx.astype(np.int32),
                        live.astype(np.float32)))
        return out

    def onesided_state(self, payload_elems: int, dtype=jnp.float32):
        """Fresh (recv buffers, signal counters) for the executing scan.

        ``recv[s]`` is the ``a2a_cap``-row buffer rank ``s`` puts into on
        this rank; ``sig[s]`` counts the epochs rank ``s`` has signalled.
        """
        recv = jnp.zeros((self.ndev, self.a2a_cap, payload_elems), dtype)
        sig = jnp.zeros((self.ndev,), jnp.int32)
        return recv, sig

    def onesided_push(self, payload, recv, sig):
        """The producer side: put dependency rows into each consumer's
        receive buffer and raise its signal (``put`` + ``putmem_signal``).

        The portable emulation moves each (rows, flag) packet with one
        point-to-point ``ppermute`` per active ring offset — the flag
        travels *with* the payload, so the signal is genuinely raised by
        the producer, not inferred by the consumer.  Slot writes use
        ``.at[...].set(mode="drop")`` like the token-dispatch path.
        """
        if self.a2a_cap == 0:
            return recv, sig
        rank = jax.lax.axis_index(self.axis)
        P = payload.shape[-1]
        for off, idx_tab, flag_tab in self._onesided_offsets:
            idx = jnp.take(jnp.asarray(idx_tab), rank, axis=0)   # (cap,)
            block = jnp.take(payload, idx, axis=0)               # (cap, P)
            flag = jnp.take(jnp.asarray(flag_tab), rank)
            packet = jnp.concatenate(
                [block, jnp.full((1, P), flag, block.dtype)])
            perm = [(r, (r + off) % self.ndev) for r in range(self.ndev)]
            got = jax.lax.ppermute(packet, self.axis, perm)
            src = jax.lax.rem(rank - off + self.ndev, self.ndev)
            recv = recv.at[src].set(got[:-1], mode="drop")
            sig = sig.at[src].add(got[-1, 0].astype(jnp.int32), mode="drop")
        return recv, sig

    def onesided_wait(self, recv, sig, t, payload):
        """The consumer side: ``signal_wait_until`` + context assembly.

        Receive slots whose producer has not signalled epoch ``t`` yet
        read as zeros (the masked wait) — which is also what makes the
        mode bit-exact with blocking: dead pairs and the t=0 epoch are
        masked instead of synchronized away.
        """
        if self.a2a_cap == 0:
            return payload
        ready = sig >= jnp.asarray(t).astype(sig.dtype)
        slots = jnp.where(ready[:, None, None], recv, jnp.zeros_like(recv))
        return jnp.concatenate(
            [slots.reshape(self.ndev * self.a2a_cap, -1), payload])


def _padded_static_inputs(graph: TaskGraph, padded: int):
    """Dep matrices (H, padded, padded) u8 + iteration counts (H, padded)."""
    from ..backends import body  # local import: backends import this module

    mats, iters = body.graph_static_inputs(graph)
    W = graph.width
    if padded == W:
        return mats, iters
    H = graph.height
    pm = np.zeros((H, padded, padded), np.uint8)
    pm[:, :W, :W] = mats
    pi = np.zeros((H, padded), np.int32)  # dead columns: no work
    pi[:, :W] = iters
    return pm, pi


def plan_comm(
    graph: TaskGraph,
    ndev: int,
    axis: str,
    comm: str = "auto",
    prefer_ring: bool = False,
    comm_overlap: bool = False,
) -> CommPlan:
    """Build the communication plan for ``graph`` over ``ndev`` ranks.

    ``comm`` forces a mode; ``auto`` picks the cheapest legal one (never
    ``a2a`` or ``onesided``, which must be requested — per-pair buffers
    only beat the allgather when the dependence relation is sparse, and
    put/signal trades rendezvous latency for buffer space).  With
    ``prefer_ring`` (pipeline backends), graphs whose deps reach only
    toward lower columns use the one-directional ring instead of the
    bidirectional halo.  ``comm_overlap`` asks the executing backend for
    the double-buffered program shape (next step's exchange issued ahead
    of this step's kernel body); results are bit-identical either way.
    """
    if comm not in MODES:
        raise ValueError(f"unknown comm mode {comm!r}; known: {MODES}")
    if ndev < 1:
        raise ValueError(f"need at least one rank, got {ndev}")
    W, H = graph.width, graph.height
    padded = -(-W // ndev) * ndev
    local = padded // ndev
    left, right = directional_reach(graph)
    reach = max(left, right)

    if comm == "auto":
        if reach > local:
            mode = "allgather"
        elif prefer_ring and right == 0:
            mode = "ring"
        else:
            mode = "halo"
    else:
        mode = comm
        if mode == "ring" and right > 0:
            raise ValueError(
                f"ring comm needs left-only deps, but reach is "
                f"(left={left}, right={right})")
        if mode in ("ring", "halo") and reach > local:
            raise ValueError(
                f"{mode} comm cannot cover reach {reach} with "
                f"{local} columns per rank; use allgather")

    mats, iters = _padded_static_inputs(graph, padded)
    if mode in ("a2a", "onesided"):
        plan = _plan_a2a(graph, ndev, axis, mats, iters, padded, local,
                         mode=mode)
        return dataclasses.replace(plan, comm_overlap=comm_overlap) \
            if comm_overlap else plan
    if mode == "allgather":
        halo = 0
        lmats = mats  # context is the full gathered (padded) width
    else:
        halo = min(reach if mode == "halo" else left, local)
        lhalo, rhalo = halo, (halo if mode == "halo" else 0)
        ctx = lhalo + local + rhalo
        lmats = np.zeros((H, padded, ctx), np.uint8)
        t_idx, i_idx, j_idx = np.nonzero(mats)
        # re-index dep columns into [left halo | local block | right halo]
        lj = j_idx - ((i_idx // local) * local - lhalo)
        assert ((0 <= lj) & (lj < ctx)).all(), (mode, halo, local)
        lmats[t_idx, i_idx, lj] = 1

    return CommPlan(
        mode=mode, axis=axis, ndev=ndev, width=W, padded_width=padded,
        local=local, halo=halo, local_mats=lmats, iters=iters,
        comm_overlap=comm_overlap,
    )


def _plan_a2a(graph: TaskGraph, ndev: int, axis: str,
              mats: np.ndarray, iters: np.ndarray,
              padded: int, local: int, mode: str = "a2a") -> CommPlan:
    """Per-pair dispatch plan: rank ``src`` sends rank ``dst`` exactly the
    payload columns ``dst``'s tasks read from ``src``'s block (union over
    timesteps, one plan reused per step like the halo modes).  Buffers are
    padded to the max pair count; unused send slots carry an arbitrary
    local row that no ``local_mats`` entry references.

    ``onesided`` shares this slot layout byte-for-byte — only the
    transport differs (producer puts + signals instead of the collective
    ``all_to_all``), so conformance between the modes is structural.
    """
    H = graph.height
    t_idx, i_idx, j_idx = np.nonzero(mats)
    src, dst = j_idx // local, i_idx // local
    remote = src != dst
    # unique (src, dst, j) triples, lexically sorted — fixes the slot order
    triples = np.unique(
        np.stack([src[remote], dst[remote], j_idx[remote]], axis=1), axis=0)
    send_counts = np.zeros((ndev, ndev), np.int64)
    for s, d, _ in triples:
        send_counts[s, d] += 1
    cap = int(send_counts.max()) if triples.size else 0
    send_idx = np.zeros((ndev, ndev, cap), np.int32)
    # context offset of remote column j for its consumer rank:
    # [recv buffers (ndev * cap, src-major) | local block]
    col_off = {}
    slot = np.zeros((ndev, ndev), np.int64)
    for s, d, j in triples:
        k = slot[s, d]
        slot[s, d] += 1
        send_idx[s, d, k] = j - s * local
        col_off[(d, j)] = s * cap + k
    ctx = ndev * cap + local
    lmats = np.zeros((H, padded, ctx), np.uint8)
    for t, i, j in zip(t_idx, i_idx, j_idx):
        r = i // local
        off = (ndev * cap + (j - r * local)) if j // local == r \
            else col_off[(r, j)]
        lmats[t, i, off] = 1
    return CommPlan(
        mode=mode, axis=axis, ndev=ndev, width=graph.width,
        padded_width=padded, local=local, halo=0, local_mats=lmats,
        iters=iters, send_counts=send_counts, a2a_cap=cap,
        a2a_send_idx=send_idx,
    )


# ---------------------------------------------- dynamic token all-to-all
def dispatch_capacity(sends: int, ndev: int, factor: float) -> int:
    """Rows per destination-rank buffer for ``sends`` routed items.

    ``factor`` is the MoE capacity factor; the result is padded to a
    multiple of 8 (TPU sublane) with a floor of 8 so tiny shards still
    form a legal tile.  Sends beyond a destination's capacity are dropped
    deterministically in send order (``TokenA2APlan.route``).
    """
    return max(8, int(math.ceil(factor * sends / ndev / 8.0) * 8))


@dataclasses.dataclass(frozen=True)
class TokenA2APlan:
    """Routing-dependent all-to-all over ``axis`` (MoE dispatch/combine).

    The static part — ``cap`` rows per destination, slot assignment by
    arrival order, forward/reverse ``all_to_all`` — is planned here; the
    per-row destinations arrive at runtime from the router.  All methods
    run *inside* ``shard_map``.  Volume per rank per direction:
    ``ndev * cap`` rows — the quantity the SP-aware MoE cuts by sharding
    tokens over the ``model`` axis before planning.
    """

    axis: str
    ndev: int
    cap: int

    def route(self, dest):
        """dest (M,) int32 -> (slot, keep).

        ``slot`` is each row's arrival index among same-destination rows
        (deterministic in send order — the paper-style capacity drop);
        rows with ``slot >= cap`` are parked on the overflow slot ``cap``
        and masked by ``keep``.
        """
        onehot = jax.nn.one_hot(dest, self.ndev, dtype=jnp.int32)
        slot = jnp.cumsum(onehot, axis=0) - onehot
        slot = (slot * onehot).sum(-1)
        keep = slot < self.cap
        return jnp.where(keep, slot, self.cap), keep

    def dispatch(self, dest, slot, rows, fill=0):
        """Exchange rows (M, ...) toward their destination ranks.

        Returns this rank's received rows, flattened to ``(ndev * cap,
        ...)``: row ``s * cap + k`` is the k-th row rank ``s`` sent here.
        Empty/overflow slots hold ``fill``.
        """
        shape = (self.ndev, self.cap + 1) + rows.shape[1:]
        buf = jnp.full(shape, fill, rows.dtype)
        buf = buf.at[dest, slot].set(rows, mode="drop")[:, : self.cap]
        recv = jax.lax.all_to_all(buf, self.axis, 0, 0)
        return recv.reshape((self.ndev * self.cap,) + rows.shape[1:])

    def combine(self, out_rows, dest, slot):
        """Reverse exchange: out_rows ``(ndev * cap, ...)`` keyed like
        ``dispatch``'s result travel back to the senders; returns one row
        per original send (M, ...).  Dropped sends read the overflow slot
        — mask the result with ``keep`` from ``route``.
        """
        back = jax.lax.all_to_all(
            out_rows.reshape((self.ndev, self.cap) + out_rows.shape[1:]),
            self.axis, 0, 0)
        return back[dest, jnp.clip(slot, 0, self.cap - 1)]
