"""Logical-axis sharding rules with divisibility fallback.

Every tensor in the repo carries *logical* axis names (``"embed"``,
``"heads"``, ``"batch"``...) rather than concrete mesh axes.  A
``ShardingRules`` table maps each logical axis to an ordered list of
*candidate* mesh placements; resolution walks the tensor's axes
left-to-right and, per axis, takes the first candidate that

  * names only mesh axes that exist in the mesh,
  * names only mesh axes not already used by this tensor
    (a mesh axis shards at most one dim of any tensor), and
  * evenly divides the dimension (the *divisibility fallback*:
    Arctic's 56 heads don't divide a 16-way ``model`` axis, so heads
    replicate and attention runs context-parallel instead — no
    per-arch special-casing).

A candidate may be a single mesh axis (``"model"``) or a tuple
(``("pod", "data")``) whose product shards one dim — how the batch and
FSDP dims span pods on the multi-pod mesh.

``use_rules``/``active_rules`` install a rules table for a region of
code; ``constrain`` is the model-side hook that turns logical axes into
``with_sharding_constraint`` (and is a no-op outside any rules context,
so single-device tests run the exact same model code).
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# one candidate mesh placement: a mesh axis or a tuple sharding jointly
Candidate = Union[str, Tuple[str, ...]]


@dataclasses.dataclass
class ShardingRules:
    """A mesh (anything with a ``.shape`` axis->size mapping) + rule table."""

    mesh: Any
    rules: Dict[Optional[str], List[Candidate]]

    def spec_for(
        self,
        axis_names: Sequence[Optional[str]],
        shapes: Sequence[int],
    ) -> P:
        """Resolve one tensor's logical axes to a PartitionSpec."""
        mesh_shape = dict(self.mesh.shape)
        used: set = set()
        entries: List[Optional[Candidate]] = []
        for name, dim in zip(axis_names, shapes):
            pick: Optional[Candidate] = None
            for cand in self.rules.get(name, []) if name is not None else []:
                axes = (cand,) if isinstance(cand, str) else tuple(cand)
                if any(a not in mesh_shape for a in axes):
                    continue  # e.g. ("pod","data") on a single-pod mesh
                if any(a in used for a in axes):
                    continue  # mesh axis already shards another dim
                size = int(np.prod([mesh_shape[a] for a in axes]))
                if dim % size:
                    continue  # divisibility fallback: try the next candidate
                pick = axes[0] if len(axes) == 1 else axes
                used.update(axes)
                break
            entries.append(pick)
        return P(*entries)

    def sharding_for(
        self,
        axis_names: Sequence[Optional[str]],
        shapes: Sequence[int],
    ) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(axis_names, shapes))


def _tp_fsdp_sp_rules() -> Dict[Optional[str], List[Candidate]]:
    fsdp: List[Candidate] = [("pod", "data"), "data"]
    tp: List[Candidate] = ["model"]
    return {
        # activations
        "batch": list(fsdp),
        "seq": list(tp),        # sequence-parallel residual layout
        "seq_full": [],         # replicated sequence inside attention/FFN
        # MoE region: SP-aware expert parallelism keeps the sequence
        # sharded over `model` so each plane all-to-alls only its shard
        # (models.moe ep_mode="sp"; divisibility fallback -> replicated)
        "seq_moe": list(tp),
        "kv_seq": [],
        "act_heads": list(tp),
        "kv_heads_act": list(tp),
        "act_ffn": list(tp),
        "vocab_out": list(tp),
        # parameters
        "embed": list(fsdp),
        "embed2": [],           # norm scales/biases replicate
        "vocab": list(tp),
        "heads": list(tp),
        "kv_heads": list(tp),
        "head_dim": [],
        "ffn": list(tp),
        "expert": list(fsdp),   # expert parallelism over the data axis
        "expert_embed": [],
        "expert_ffn": list(tp),
        "ssm_inner": list(tp),
        "ssm_heads": list(tp),
        "lru": list(tp),
        "conv_k": [],
        "layers": [],           # scanned-stack leading dim stays unsharded
        # pipeline: the stage-stacked block dim lives on the stage axis
        # (skipped on meshes without one — same code runs 3D and 4D)
        "stage": ["stage"],
    }


def _dp_only_rules() -> Dict[Optional[str], List[Candidate]]:
    """Naive data parallelism: batch over (pod x) data, replicate the rest."""
    return {"batch": [("pod", "data"), "data"]}


_STRATEGIES = {
    "tp+fsdp+sp": _tp_fsdp_sp_rules,
    "dp_only": _dp_only_rules,
}


def make_rules(mesh, strategy: str = "tp+fsdp+sp") -> ShardingRules:
    if strategy not in _STRATEGIES:
        raise ValueError(
            f"unknown sharding strategy {strategy!r}; known: {sorted(_STRATEGIES)}")
    return ShardingRules(mesh=mesh, rules=_STRATEGIES[strategy]())


# ------------------------------------------------------- active-rules context
_ACTIVE: List[ShardingRules] = []


@contextmanager
def use_rules(rules: ShardingRules):
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def active_rules() -> Optional[ShardingRules]:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x, *axes):
    """Constrain ``x`` to its logical-axes layout under the active rules.

    Identity when no rules are active, so model code is oblivious to
    whether it runs single-device or sharded.
    """
    rules = active_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec_for(axes, x.shape)))
