"""int8 gradient compression: error feedback + compressed all-reduce.

``ef_compress`` implements the classic error-feedback scheme (1-bit
Adam / EF-SGD lineage): quantize ``grad + residual`` to int8 with a
per-tensor scale, carry the quantization error into the next step's
residual.  The compressed value plus the new residual reconstructs the
input exactly, so the scheme is unbiased over time.

``compressed_psum`` is the collective analogue: ranks agree on a global
scale (one scalar pmax), transmit int8 payloads, and sum them as int32
— an all-reduce at one quarter of fp32 bandwidth with worst-case error
``0.5 * scale`` per participating shard.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

_QMAX = 127.0  # symmetric int8 range


def _safe(scale):
    return jnp.where(scale > 0, scale, 1.0)


def ef_compress(grad, residual) -> Tuple[jax.Array, jax.Array]:
    """-> (dequantized int8 value, new residual); value + residual == input."""
    v = grad.astype(jnp.float32) + residual
    scale = _safe(jnp.max(jnp.abs(v)) / _QMAX)
    q = jnp.clip(jnp.round(v / scale), -_QMAX, _QMAX).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, v - deq


def ef_compress_tree(grads: Any, residuals: Any) -> Tuple[Any, Any]:
    """Per-leaf ``ef_compress`` -> (compressed grads tree, residuals tree)."""
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(residuals)
    outs = [ef_compress(g, r) for g, r in zip(leaves, res_leaves)]
    return (jax.tree.unflatten(treedef, [c for c, _ in outs]),
            jax.tree.unflatten(treedef, [r for _, r in outs]))


def compressed_psum(v, axis_name) -> jax.Array:
    """Quantized cross-device all-reduce (call inside ``shard_map``).

    One scalar pmax establishes a shared scale; payloads travel as int8
    (summed in int32 — no overflow below 2^24 participants) and are
    rescaled once.  Error <= 0.5 * scale per shard.
    """
    v32 = v.astype(jnp.float32)
    scale = _safe(jax.lax.pmax(jnp.max(jnp.abs(v32)), axis_name) / _QMAX)
    q = jnp.clip(jnp.round(v32 / scale), -_QMAX, _QMAX).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale).astype(v.dtype)
