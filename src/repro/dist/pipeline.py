"""Pipeline parallelism mapped onto the paper's *sweep* dependence pattern.

A pipeline schedule over S stages and M microbatches IS a sweep task
graph (paper Table 2): task ``(t, s)`` — clock tick t, stage s — depends
on ``(t-1, s-1)`` (the activation arriving from the previous stage) and
``(t-1, s)`` (the stage's own previous microbatch, the in-order
constraint).  ``pp_schedule`` returns that graph; ``pp_forward``
executes it wavefront-by-wavefront, so the execution order is exactly
the order a pipelined runtime would realize, while the numerics match
the non-pipelined reference bit-for-tolerance.

Stages slice the scanned homogeneous block stack: stage ``s`` owns
layers ``[s*L/S, (s+1)*L/S)``.  Stage 0 additionally embeds tokens; the
last stage feeds the final norm + unembed.

Under a 4D ``(pod, data, model, stage)`` mesh (see
``launch.mesh.make_production_mesh(pipeline_stages=...)``) the stacked
stage dim is sharded over the ``stage`` axis via
``constrain_stage_stack``, so each stage's weights live on their own
mesh plane; the sweep *task graph itself* can also be executed directly
by the ``shardmap-pipeline`` backend, which moves payloads stage-to-
stage with a ``ppermute`` ring (``dist.collectives``).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.graph import TaskGraph, make_graph
from ..models import layers as L
from ..models import model as M
from .sharding import constrain


def pp_schedule(num_stages: int, num_micro: int) -> TaskGraph:
    """The pipeline schedule as a sweep task graph.

    width = stages, height = micro + stages - 1 clock ticks (fill +
    steady state + drain); microbatch ``m`` runs on stage ``s`` at tick
    ``t = m + s``.
    """
    return make_graph(
        width=num_stages,
        height=num_micro + num_stages - 1,
        pattern="sweep",
        iterations=1,
    )


def stack_params_by_stage(params: Dict, num_stages: int) -> Dict:
    """Reshape the scanned (L, ...) block stack to (stages, L/stages, ...)."""
    if "blocks_scanned" not in params:
        raise ValueError(
            "pipeline parallelism requires a scanned homogeneous block stack")
    blocks = params["blocks_scanned"]
    depth = jax.tree.leaves(blocks)[0].shape[0]
    if depth % num_stages:
        raise ValueError(f"{depth} layers not divisible by {num_stages} stages")
    out = {k: v for k, v in params.items() if k != "blocks_scanned"}
    out["blocks_scanned"] = jax.tree.map(
        lambda x: x.reshape((num_stages, depth // num_stages) + x.shape[1:]),
        blocks)
    return out


def constrain_stage_stack(pp_params: Dict) -> Dict:
    """Pin the stage-stacked blocks to the ``stage`` mesh axis.

    Under a 4D ``(pod, data, model, stage)`` rules context the leading
    (stage) dim of every stacked block leaf is sharded over ``stage``, so
    each pipeline stage's weights live on its own mesh plane and XLA
    moves only the activations stage-to-stage.  Identity outside a rules
    context or on meshes without a ``stage`` axis.
    """
    if "blocks_scanned" not in pp_params:
        return pp_params
    out = {k: v for k, v in pp_params.items() if k != "blocks_scanned"}
    out["blocks_scanned"] = jax.tree.map(
        lambda x: constrain(x, "stage", *([None] * (x.ndim - 1))),
        pp_params["blocks_scanned"])
    return out


def _run_stage(pp_params: Dict, stage: int, h, cfg, positions):
    """-> (h', stage MoE aux (lb, zl) summed over the stage's layers)."""
    kind = cfg.pattern_for_depth()[0]
    stage_blocks = jax.tree.map(lambda x: x[stage],
                                pp_params["blocks_scanned"])
    zero = jnp.zeros((), jnp.float32)

    def body(carry, layer_params):
        x, lb, zl = carry
        x, _, (lb_i, zl_i) = M.apply_block(layer_params, kind, x, cfg,
                                           positions)
        return (x, lb + lb_i, zl + zl_i), None

    (h, lb, zl), _ = jax.lax.scan(body, (h, zero, zero), stage_blocks)
    return h, (lb, zl)


def _pp_forward_with_aux(pp_params: Dict, cfg, tokens, num_stages: int,
                         num_micro: int):
    """Pipelined forward -> (logits, aux); numerics match M.forward.

    MoE aux losses sum over layers (as in the reference) and average
    over microbatches (router statistics are per-microbatch under
    pipelining, the same treatment gradient accumulation applies).
    """
    B, S = tokens.shape
    if B % num_micro:
        raise ValueError(f"batch {B} not divisible by {num_micro} microbatches")
    pp_params = constrain_stage_stack(pp_params)
    mb = B // num_micro
    positions = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32)[None, :], (mb, S))

    sched = pp_schedule(num_stages, num_micro)
    acts: Dict[Tuple[int, int], Any] = {}  # (stage, micro) -> activation
    outs = [None] * num_micro
    lb = zl = jnp.zeros((), jnp.float32)
    for t in range(sched.height):  # wavefront clock
        for s in range(num_stages):
            m = t - s
            if not (0 <= m < num_micro):
                continue
            if s == 0:
                h = L.apply_embedding(pp_params["embed"],
                                      tokens[m * mb:(m + 1) * mb])
                h = constrain(h, "batch", "seq", None)
            else:
                h = acts.pop((s - 1, m))
            h, (lb_i, zl_i) = _run_stage(pp_params, s, h, cfg, positions)
            lb, zl = lb + lb_i, zl + zl_i
            if s == num_stages - 1:
                outs[m] = h
            else:
                acts[(s, m)] = h

    h = jnp.concatenate(outs, axis=0)
    h = L.apply_norm(pp_params["final_norm"], h, cfg.norm, cfg.norm_eps)
    head = pp_params["embed"] if cfg.tie_embeddings else pp_params["head"]
    logits = L.apply_unembed(head, h)
    logits = constrain(logits, "batch", "seq", "vocab_out")
    inv = 1.0 / num_micro
    return logits, {"moe_lb_loss": lb * inv, "moe_z_loss": zl * inv}


def pp_forward(pp_params: Dict, cfg, tokens, num_stages: int,
               num_micro: int):
    """Pipelined forward pass -> logits, numerically matching M.forward."""
    logits, _ = _pp_forward_with_aux(pp_params, cfg, tokens, num_stages,
                                     num_micro)
    return logits


def pp_loss_fn(pp_params: Dict, cfg, batch: Dict, num_stages: int,
               num_micro: int):
    """Next-token loss over the pipelined forward -> (total, metrics).

    Same objective as ``train_step.loss_fn``: shared token loss plus
    the MoE aux terms with the same coefficients.
    """
    from ..train.train_step import MOE_LB_COEF, MOE_Z_COEF, token_loss

    logits, aux = _pp_forward_with_aux(pp_params, cfg, batch["tokens"],
                                       num_stages, num_micro)
    nll, zloss = token_loss(logits, batch["labels"])
    total = (nll + zloss
             + MOE_LB_COEF * aux["moe_lb_loss"]
             + MOE_Z_COEF * aux["moe_z_loss"])
    return total, {"loss": nll, "z_loss": zloss,
                   "moe_lb_loss": aux["moe_lb_loss"],
                   "total_loss": total}
