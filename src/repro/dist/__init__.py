"""Distribution subsystem: sharding rules, compressed collectives,
pipeline parallelism.

Submodules are imported directly (``from repro.dist import sharding``)
rather than re-exported here: ``models``/``optim`` import
``dist.sharding`` at module load, so an eager import of
``dist.pipeline`` (which imports ``models``) would create a cycle.
"""
