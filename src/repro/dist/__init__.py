"""Distribution subsystem: sharding rules, comm planning, compressed
collectives, pipeline parallelism.

``collectives`` is the shared comm-planning layer: ``CommPlan`` decides
how task-graph payloads move between ranks (ring / halo / allgather,
with ragged-width padding) and is consumed by the ``shardmap-csp`` and
``shardmap-pipeline`` backends.

Submodules are imported directly (``from repro.dist import sharding``)
rather than re-exported here: ``models``/``optim`` import
``dist.sharding`` at module load, so an eager import of
``dist.pipeline`` (which imports ``models``) would create a cycle.
"""
