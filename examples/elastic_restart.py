"""Fault tolerance demo: crash mid-run, restart, verify bit-exact resume.

Run: PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

import jax

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.train import train_step as TS
from repro.train.trainer import LoopConfig, Trainer


def main():
    cfg = reduced(get_config("yi-6b"))
    tcfg = TS.TrainConfig(base_lr=1e-3, warmup_steps=4, total_steps=60)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    d = tempfile.mkdtemp(prefix="repro_elastic_")
    loop = LoopConfig(num_steps=24, ckpt_dir=d, ckpt_every=8, log_every=0)

    ref = Trainer(cfg, tcfg, dcfg, loop)
    ref.run(jax.random.PRNGKey(0))
    ref_losses = {m["step"]: m["loss"] for m in ref.metrics_log}
    print(f"reference run: {len(ref_losses)} steps")

    d2 = tempfile.mkdtemp(prefix="repro_elastic_b_")
    loop2 = LoopConfig(num_steps=24, ckpt_dir=d2, ckpt_every=8, log_every=0)
    crashed = Trainer(cfg, tcfg, dcfg, loop2)
    try:
        crashed.run(jax.random.PRNGKey(0), fail_at=13)
    except RuntimeError as e:
        print(f"crash injected: {e}")

    resumed = Trainer(cfg, tcfg, dcfg, loop2)
    resumed.run(jax.random.PRNGKey(0))
    first = resumed.metrics_log[0]["step"]
    exact = all(m["loss"] == ref_losses[m["step"]]
                for m in resumed.metrics_log)
    print(f"resumed from checkpointed step {first} "
          f"(crash was at 13); losses bit-exact vs reference: {exact}")
    assert exact


if __name__ == "__main__":
    main()
