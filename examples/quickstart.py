"""Quickstart: end-to-end training with the full substrate on CPU.

Trains a reduced Qwen1.5-family model on the synthetic pipeline for a few
hundred steps with checkpointing, then resumes from the checkpoint to show
restart-determinism.  (Full-size configs are exercised via the multi-pod
dry-run: `python -m repro.launch.dryrun`.)

Run: PYTHONPATH=src python examples/quickstart.py [--steps 200]
"""
import argparse
import tempfile

import jax

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.train import train_step as TS
from repro.train.trainer import LoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"arch: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")
    tcfg = TS.TrainConfig(base_lr=1e-3, warmup_steps=20,
                          total_steps=args.steps, grad_accum=1)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      embed_dim=cfg.d_model if cfg.frontend else 0)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_quickstart_")
    loop = LoopConfig(num_steps=args.steps, ckpt_dir=ckpt_dir,
                      ckpt_every=max(args.steps // 4, 1), log_every=20)

    trainer = Trainer(cfg, tcfg, dcfg, loop)
    state = trainer.run(jax.random.PRNGKey(0))
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    print(f"checkpoints in {ckpt_dir}; straggler events: "
          f"{len(trainer.straggler_events)}")

    # resume determinism: a fresh trainer continues from the checkpoint
    loop2 = LoopConfig(num_steps=args.steps + 10, ckpt_dir=ckpt_dir,
                       ckpt_every=1000, log_every=5)
    trainer2 = Trainer(cfg, tcfg, dcfg, loop2)
    trainer2.run(jax.random.PRNGKey(0))
    print(f"resumed at step {trainer2.metrics_log[0]['step']} and ran to "
          f"{trainer2.metrics_log[-1]['step'] + 1}")


if __name__ == "__main__":
    main()
