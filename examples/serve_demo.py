"""Serve a small model with batched requests (continuous batching).

Run: PYTHONPATH=src python examples/serve_demo.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.layers import split_leaves
from repro.serve.engine import ServeEngine


def main():
    cfg = reduced(get_config("mixtral-8x7b"))  # MoE family, ring KV cache
    params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
    engine = ServeEngine(cfg, params, batch_slots=3, max_len=128)

    rng = np.random.RandomState(0)
    rids = [engine.submit(rng.randint(1, cfg.vocab_size, size=n),
                          max_new_tokens=m)
            for n, m in [(5, 8), (3, 4), (9, 6), (2, 10), (7, 5)]]
    print(f"submitted {len(rids)} requests into 3 batch slots")
    out = engine.run()
    for rid in rids:
        print(f"  request {rid}: {len(out[rid])} tokens -> {out[rid]}")


if __name__ == "__main__":
    main()
