"""Serve a small model with continuous batching (slot-granular admission).

Five requests share three persistent batch slots: each request prefills
unpadded at batch 1 the moment a slot frees up (mid-decode for everyone
else) and decodes in on-device chunks — the host syncs once per chunk,
not once per token.  The engine stats printed at the end show the sync
arithmetic; rerun with ``decode_mode="host"`` to see the per-token
baseline pay one round-trip per generated token.

Run: PYTHONPATH=src python examples/serve_demo.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.layers import split_leaves
from repro.serve.engine import ServeEngine


def main():
    cfg = reduced(get_config("mixtral-8x7b"))  # MoE family, ring KV cache
    params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
    engine = ServeEngine(cfg, params, batch_slots=3, max_len=128,
                         chunk_size=4, decode_mode="chunked")

    rng = np.random.RandomState(0)
    rids = [engine.submit(rng.randint(1, cfg.vocab_size, size=n),
                          max_new_tokens=m)
            for n, m in [(5, 8), (3, 4), (9, 6), (2, 10), (7, 5)]]
    # eos early-stop: this request halts as soon as it emits token 7
    rids.append(engine.submit(rng.randint(1, cfg.vocab_size, size=4),
                              max_new_tokens=12, eos_id=7))
    print(f"submitted {len(rids)} requests into {engine.slots} batch slots")
    out = engine.run()
    for rid in rids:
        print(f"  request {rid}: {len(out[rid])} tokens -> {out[rid]}")
    s = engine.stats
    print(f"stats: {s['prefills']} prefills, {s['decode_steps']} decode "
          f"steps in {s['chunk_launches']} chunk launches, "
          f"{s['host_syncs']} host syncs for {s['tokens_generated']} tokens")


if __name__ == "__main__":
    main()
