"""The paper's core experiment: METG(50%) across systems and patterns.

Reproduces the Figure 9 methodology on the four JAX execution backends
(paper Table 4 analogues) x four dependence patterns, printing the METG
table and one efficiency-vs-granularity curve (Figure 3 analogue).

Run: PYTHONPATH=src python examples/metg_study.py [--fast]
"""
import argparse
import sys

sys.path.insert(0, ".")  # for benchmarks.common when run from repo root

from benchmarks.common import BenchContext, metg_for
from repro.backends import backend_names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--artifacts", default=None,
                    help="directory for BENCH_<scenario>.json files")
    args = ap.parse_args()
    n_points = 5 if args.fast else 7
    ctx = BenchContext(artifacts_dir=args.artifacts)

    cases = [("stencil", {}, 1), ("nearest", {"radix": 5}, 1),
             ("spread", {"radix": 5}, 1), ("nearest", {"radix": 5}, 4)]

    print(f"{'backend':14s} {'pattern':12s} {'METG(50%) us':>12s} "
          f"{'peak GFLOP/s':>13s}")
    for be in backend_names():
        hi = 512 if (args.fast or be == "host-dynamic") else 4096
        for pat, kw, ng in cases:
            name = pat + ("_x4" if ng > 1 else "")
            res = metg_for(ctx, be, pat, name=f"metg_study.{be}.{name}",
                           num_graphs=ng, iterations_hi=hi,
                           n_points=n_points, **kw)
            metg = (res.metg or float("nan")) * 1e6
            print(f"{be:14s} {name:12s} {metg:12.2f} "
                  f"{res.peak_rate / 1e9:13.2f}")

    print("\nefficiency vs granularity (xla-scan, stencil) — Fig 3 analogue:")
    res = metg_for(ctx, "xla-scan", "stencil",
                   name="metg_study.curve", iterations_hi=4096, n_points=8)
    for p in sorted(res.points, key=lambda p: -p.granularity):
        bar = "#" * int(p.efficiency * 40)
        print(f"  {p.granularity * 1e6:10.2f} us  {p.efficiency * 100:5.1f}% {bar}")
    print(f"  METG(50%) = {(res.metg or 0) * 1e6:.2f} us")


if __name__ == "__main__":
    main()
