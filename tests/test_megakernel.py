"""The fused megakernel backend: structure, tables, model, baselines.

Bit-exact conformance of ``pallas-fused`` rides the shared matrices in
test_conformance.py / test_backends.py (it registers like any backend).
This file pins what is *specific* to the tentpole:

* the fusion claim itself — the TPU lowering of the fused program is a
  single kernel launch with no dispatch loop, while ``xla-scan``'s is a
  ``while`` loop with no kernel launch (structural, not clock-based);
* the dense dependency-table form the kernel consumes;
* the per-launch synthetic dispatch model and the committed baselines
  showing the METG undercut.
"""
import json
import os

import numpy as np
import pytest

from repro.backends import backend_names, get_backend
from repro.core import (check_outputs, execute_reference, make_graph,
                        pattern_names, replicate)

BASELINES = os.path.join(os.path.dirname(__file__), "..",
                         "benchmarks", "baselines")


def small_graph(**kw):
    kw.setdefault("width", 8)
    kw.setdefault("height", 6)
    kw.setdefault("pattern", "stencil")
    kw.setdefault("iterations", 4)
    return make_graph(**kw)


# ------------------------------------------------------------ registration
def test_registered_with_fused_dispatch_model():
    assert "pallas-fused" in backend_names()
    be = get_backend("pallas-fused")
    assert be.dispatch_model == "per-launch"
    # CPU hosts auto-select interpret mode; the option is spellable too
    assert be.interpret is True
    assert get_backend("pallas-fused[interpret=True]").interpret is True


# ------------------------------------------------------- the fusion claim
def test_fused_program_is_a_single_kernel_launch():
    """The tentpole, pinned structurally: all H timesteps of the graph
    lower into exactly one Pallas launch (`tpu_custom_call`) and no
    dispatch loop, while xla-scan's program is a `stablehlo.while` that
    re-dispatches its body every timestep."""
    g = small_graph()
    fused = get_backend("pallas-fused").lowered_stablehlo([g])
    assert fused.count("tpu_custom_call") == 1
    assert "stablehlo.while" not in fused

    scan = get_backend("xla-scan").lowered_stablehlo([g])
    assert "tpu_custom_call" not in scan
    assert scan.count("stablehlo.while") >= 1


def test_fused_concurrent_graphs_still_one_launch():
    """Multi-graph scenarios fuse through the leading grid dimension:
    even 3 concurrent graphs cost ONE launch (xla-scan pays one while
    loop regardless, but each iteration dispatches its ops again)."""
    g = small_graph()
    fused = get_backend("pallas-fused").lowered_stablehlo(replicate(g, 3))
    assert fused.count("tpu_custom_call") == 1
    assert "stablehlo.while" not in fused


# --------------------------------------------------- dense dependency form
@pytest.mark.parametrize("pattern", pattern_names())
def test_dependency_table_matches_deps_lists(pattern):
    """The padded (H, W, R) table is exactly the deps() lists in sorted
    order, with dead slots masked (ragged-padding idiom)."""
    g = make_graph(width=6, height=8, pattern=pattern, iterations=2,
                   **({"radix": 3} if pattern in ("nearest", "spread")
                      else {}))
    idx, mask = g.dependency_table()
    assert idx.shape == mask.shape == (g.height, g.width,
                                       max(1, g.max_radix()))
    assert idx.dtype == np.int32 and mask.dtype == np.uint8
    for t in range(g.height):
        for i in range(g.width):
            ds = g.deps(t, i)
            got = idx[t, i][mask[t, i] != 0].tolist()
            assert got == ds, (pattern, t, i)
            # padding is column 0 under mask 0
            assert (idx[t, i][mask[t, i] == 0] == 0).all()


def test_dependency_table_padding_and_validation():
    g = make_graph(width=6, height=4, pattern="stencil", iterations=2)
    idx, mask = g.dependency_table()
    r0 = idx.shape[2]
    wide_idx, wide_mask = g.dependency_table(r0 + 2)
    assert wide_idx.shape[2] == r0 + 2
    assert (wide_idx[..., :r0] == idx).all()
    assert (wide_mask[..., r0:] == 0).all()
    with pytest.raises(ValueError, match="radix"):
        g.dependency_table(r0 - 1)
    # cached and read-only on the frozen graph
    assert g.dependency_table()[0] is idx
    with pytest.raises(ValueError):
        idx[0, 0, 0] = 7


def test_checksum_table_matches_scalar_checksum():
    g = make_graph(width=7, height=9, pattern="trivial", iterations=1)
    tab = g.checksum_table()
    assert tab.shape == (g.height, g.width)
    for t in range(g.height):
        for i in range(g.width):
            assert int(tab[t, i]) == g.checksum(t, i)


# ----------------------------------------------- bit-exact vs the scan
def test_fused_bitwise_equal_to_scan_including_kernel_slots():
    """check_outputs compares kernel slots with tolerance; for the
    elementwise kernels the fused and scan programs must in fact agree
    *bitwise* on every slot (they trace the same kernels.bodies code)."""
    fused, scan = get_backend("pallas-fused"), get_backend("xla-scan")
    for kw in (
        dict(),
        dict(kernel="memory", span_bytes=256, scratch_bytes=2048),
        dict(pattern="nearest", radix=3, imbalance=0.8, iterations=32),
        dict(width=10, output_bytes=64),
        dict(width=3, pattern="sweep"),
    ):
        g = small_graph(**kw)
        a = np.asarray(fused.run([g])[0])
        b = np.asarray(scan.run([g])[0])
        assert (a == b).all(), kw
        check_outputs(g, a, expected=execute_reference(g))


def test_fused_run_many_bitwise_equal_to_scan():
    fused, scan = get_backend("pallas-fused"), get_backend("xla-scan")
    graphs = [small_graph(pattern=p) for p in ("stencil", "sweep", "fft")]
    for a, b in zip(fused.run_many(graphs), scan.run_many(graphs)):
        assert (np.asarray(a) == np.asarray(b)).all()


# --------------------------------------------- per-launch dispatch model
def test_synthetic_per_launch_model_closed_form():
    from repro.bench import SyntheticTimer
    from repro.bench.timers import backend_dispatch_model

    assert backend_dispatch_model("pallas-fused") == "per-launch"
    assert backend_dispatch_model("pallas-fused[interpret=True]") == \
        "per-launch"
    assert backend_dispatch_model("pallas-fused[comm=onesided]") == \
        "per-launch"
    assert backend_dispatch_model("xla-scan") == "per-task"
    # lenient: unknown and malformed names default to per-task (the
    # backend-free contract of the default synthetic configuration)
    assert backend_dispatch_model("no-such-backend") == "per-task"
    assert backend_dispatch_model("garbage[[[") == "per-task"

    t = SyntheticTimer()
    g = make_graph(width=8, height=8, pattern="stencil", iterations=64)
    expect = (t.overhead_per_launch
              + g.num_tasks * t.fused_overhead_per_task
              + g.total_iterations() * t.seconds_per_iteration)
    assert t.measure("pallas-fused", [g]) == pytest.approx(expect, rel=0,
                                                           abs=0)
    # the launch cost is charged once for the whole batch, not per graph
    two = t.measure("pallas-fused", replicate(g, 2))
    assert two == pytest.approx(
        t.overhead_per_launch + 2 * (expect - t.overhead_per_launch))
    # and the fused floor undercuts the per-task charge for this graph
    assert t.measure("pallas-fused", [g]) < t.measure("xla-scan", [g])
    # the model resolves by *name*: the spec'd backend charges the exact
    # same closed form, without ever instantiating the backend
    assert t.measure("pallas-fused[comm=onesided]", [g]) == pytest.approx(
        expect, rel=0, abs=0)


# ------------------------------------------- one-sided put/signal mode
def test_onesided_option_validated():
    assert get_backend("pallas-fused[comm=onesided]").comm == "onesided"
    with pytest.raises(ValueError, match="comm"):
        get_backend("pallas-fused[comm=ring]")


@pytest.mark.parametrize("pattern", pattern_names())
def test_onesided_bitwise_equal_to_fused(pattern):
    """The communicating kernel (remote-DMA puts + semaphore waits in
    place of in-VMEM wave reads) must be bit-exact with the single-device
    fused program on every pattern."""
    kw = {"radix": 3} if pattern in ("nearest", "spread") else {}
    g = small_graph(pattern=pattern, **kw)
    a = np.asarray(get_backend("pallas-fused[comm=onesided]").run([g])[0])
    b = np.asarray(get_backend("pallas-fused").run([g])[0])
    assert (a == b).all(), pattern
    check_outputs(g, a, expected=execute_reference(g))


def test_onesided_ragged_and_run_many():
    """Ragged widths (pad columns over the mesh) and concurrent graphs
    through the per-graph communicating kernels."""
    be = get_backend("pallas-fused[comm=onesided]")
    for kw in (dict(width=10, height=6, imbalance=1.5, iterations=5),
               dict(width=3, height=5, pattern="sweep", imbalance=2.0)):
        g = small_graph(**kw)
        check_outputs(g, be.run([g])[0], expected=execute_reference(g))
    graphs = [small_graph(pattern=p) for p in ("stencil", "sweep", "fft")]
    for g, out in zip(graphs, be.run_many(graphs)):
        check_outputs(g, out, expected=execute_reference(g))


def test_onesided_lowering_single_launch_no_xla_collectives():
    """The one-sided tentpole claim, pinned structurally on the TPU
    lowering: the whole graph is still ONE kernel launch per rank with no
    dispatch loop, and no XLA collective appears anywhere in the module —
    every cross-rank byte moves through the in-kernel remote DMA
    (put/signal), never through a ppermute/all_gather rendezvous."""
    g = small_graph()
    text = get_backend("pallas-fused[comm=onesided]").lowered_stablehlo([g])
    assert text.count("tpu_custom_call") == 1
    assert "stablehlo.while" not in text
    for op in ("collective_permute", "all_gather", "all_to_all",
               "all_reduce"):
        assert op not in text, op


# ------------------------------------------------- committed baselines
def _baseline(name):
    path = os.path.join(BASELINES, f"BENCH_{name}.json")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("case", ["stencil", "nearest", "spread",
                                  "nearest_x4"])
def test_committed_fused_baseline_undercuts_scan(case):
    """The acceptance claim, pinned on the committed snapshots the CI
    gate diffs against: on the same smoke sweep, pallas-fused's METG and
    its smallest-granularity point sit strictly below xla-scan's."""
    fused = _baseline(f"metg.pallas-fused.{case}")
    scan = _baseline(f"metg.xla-scan.{case}")
    assert fused["timer"] == scan["timer"] == "synthetic"
    assert fused["metg_s"] is not None and scan["metg_s"] is not None
    assert fused["metg_s"] < scan["metg_s"]

    fpts = {p["iterations"]: p for p in fused["points"]}
    spts = {p["iterations"]: p for p in scan["points"]}
    assert set(fpts) == set(spts), "baselines must share one sweep"
    smallest = min(fpts)
    assert (fpts[smallest]["granularity_s"]
            < spts[smallest]["granularity_s"])
    # the whole curve undercuts: same work, strictly less wall everywhere
    for it in fpts:
        assert fpts[it]["wall_time_s"] < spts[it]["wall_time_s"], it
