"""Fault tolerance: failure injection, bit-exact resume, straggler log."""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.train import train_step as TS
from repro.train.trainer import LoopConfig, Trainer


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    tcfg = TS.TrainConfig(base_lr=1e-3, warmup_steps=2, total_steps=40)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    return cfg, tcfg, dcfg


def test_failure_injection_and_bitexact_resume(setup, tmp_path):
    cfg, tcfg, dcfg = setup
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    loop = lambda d: LoopConfig(num_steps=12, ckpt_dir=d, ckpt_every=4,
                                log_every=0)

    # uninterrupted reference run
    tr_ref = Trainer(cfg, tcfg, dcfg, loop(d1))
    tr_ref.run(jax.random.PRNGKey(0))
    ref_losses = {m["step"]: m["loss"] for m in tr_ref.metrics_log}

    # crashed run: dies at step 7 (after the step-4 checkpoint)
    tr_a = Trainer(cfg, tcfg, dcfg, loop(d2))
    with pytest.raises(RuntimeError, match="injected failure"):
        tr_a.run(jax.random.PRNGKey(0), fail_at=7)

    # restart: resumes from step 4 and must reproduce losses exactly
    tr_b = Trainer(cfg, tcfg, dcfg, loop(d2))
    tr_b.run(jax.random.PRNGKey(0))
    assert tr_b.metrics_log[0]["step"] == 4
    for m in tr_b.metrics_log:
        assert m["loss"] == ref_losses[m["step"]], m["step"]


def test_straggler_watchdog(setup, tmp_path):
    cfg, tcfg, dcfg = setup
    loop = LoopConfig(num_steps=6, ckpt_dir=str(tmp_path), ckpt_every=100,
                      log_every=0, straggler_factor=0.0)  # everything flags
    tr = Trainer(cfg, tcfg, dcfg, loop)
    tr.run(jax.random.PRNGKey(0))
    assert len(tr.straggler_events) > 0
    assert {"step", "time_s", "ema_s"} <= set(tr.straggler_events[0])
