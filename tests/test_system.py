"""End-to-end system behaviour: the paper's workflow on this framework.

The full Task Bench loop: configure graphs -> run on every backend ->
self-validate -> sweep granularity -> METG; then the LM framework loop:
init -> train -> checkpoint -> serve.
"""
import jax
import numpy as np

from repro.backends import backend_names, get_backend
from repro.core import (check_outputs, compute_metg, geometric_iterations,
                        make_graph, run_sweep)


def test_every_benchmark_runs_on_every_system():
    """The O(m+n) property: all patterns x all backends, unchanged."""
    from repro.core import pattern_names

    for pattern in pattern_names():
        kw = {"radix": 3} if pattern in ("nearest", "spread") else {}
        g = make_graph(width=4, height=6, pattern=pattern, iterations=3, **kw)
        for be in backend_names():
            check_outputs(g, get_backend(be).run([g])[0])


def test_metg_measurement_end_to_end():
    be = get_backend("xla-scan")

    def graphs_at(iters):
        return [make_graph(width=4, height=16, pattern="stencil",
                           kernel="compute", iterations=iters)]

    def make_runner(iters):
        return be.prepare(graphs_at(iters))

    pts = run_sweep(make_runner, graphs_at, [2048, 256, 32, 4, 1], repeats=2)
    res = compute_metg(pts)
    assert res.peak_rate > 0
    # granularity shrinks monotonically with task size
    gs = [p.granularity for p in sorted(pts, key=lambda p: -p.iterations)]
    assert gs[0] > gs[-1]


def test_overheads_ordering_matches_paper():
    """Paper §V-C: dynamic per-task dispatch costs orders of magnitude more
    than compiled scheduling.  Compare per-task wall time at tiny tasks."""
    import time

    results = {}
    for be_name in ("xla-static", "host-dynamic"):
        be = get_backend(be_name)
        g = make_graph(width=4, height=16, pattern="stencil", iterations=1)
        runner = be.prepare([g])
        runner()
        t0 = time.perf_counter()
        runner()
        dt = time.perf_counter() - t0
        results[be_name] = dt / g.num_tasks
    assert results["host-dynamic"] > 10 * results["xla-static"], results
