"""Numerics of the shard_map'd data-parallel train step (1-device mesh).

The compression tolerance follows test_dist_smoke.py: compressed_psum's
per-tensor error is bounded by ``0.51 * max|g| / 127`` per rank, and
Adam's normalized update keeps the induced parameter drift below the
update magnitude.  Multi-rank equivalence runs in test_distributed.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, make_batch
from repro.train import dist_step as DS
from repro.train import train_step as TS
from repro.train.trainer import LoopConfig, Trainer


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    tcfg = TS.TrainConfig(base_lr=1e-3, warmup_steps=2, total_steps=40)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    return cfg, tcfg, dcfg, mesh


def run_steps(step_fn, cfg, tcfg, dcfg, n=3):
    state, _ = TS.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    losses = []
    for s in range(n):
        state, metrics = step_fn(state, make_batch(dcfg, s))
        losses.append(float(metrics["loss"]))
    return state, losses


def test_uncompressed_dp_step_matches_reference(setup):
    cfg, tcfg, dcfg, mesh = setup
    ref, l_ref = run_steps(TS.jit_train_step(cfg, tcfg), cfg, tcfg, dcfg)
    dp, l_dp = run_steps(DS.jit_dp_train_step(cfg, tcfg, mesh, compress=False),
                         cfg, tcfg, dcfg)
    np.testing.assert_allclose(l_dp, l_ref, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(dp.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_compressed_dp_step_within_compression_tolerance(setup):
    cfg, tcfg, dcfg, mesh = setup
    ref, l_ref = run_steps(TS.jit_train_step(cfg, tcfg), cfg, tcfg, dcfg)
    comp, l_comp = run_steps(
        DS.jit_dp_train_step(cfg, tcfg, mesh, compress=True),
        cfg, tcfg, dcfg)
    # int8 grad quantization perturbs each step by <= 0.51*scale/127 per
    # tensor; over 3 Adam steps the loss drift stays well under 2e-2
    np.testing.assert_allclose(l_comp, l_ref, atol=2e-2)
    assert all(np.isfinite(l) for l in l_comp)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(comp.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_grad_accum_dp_step_runs(setup):
    cfg, tcfg, dcfg, mesh = setup
    import dataclasses
    tcfg2 = dataclasses.replace(tcfg, grad_accum=2)
    _, losses = run_steps(
        DS.jit_dp_train_step(cfg, tcfg2, mesh, compress=True),
        cfg, tcfg2, dcfg, n=2)
    assert all(np.isfinite(l) for l in losses)


def test_trainer_grad_sync_flag(setup, tmp_path):
    cfg, tcfg, dcfg, mesh = setup
    loop = lambda d: LoopConfig(num_steps=4, ckpt_dir=str(tmp_path / d),
                                ckpt_every=100, log_every=0)
    ref = Trainer(cfg, tcfg, dcfg, loop("ref"))
    ref.run(jax.random.PRNGKey(0))
    tr = Trainer(cfg, tcfg, dcfg, loop("dp"),
                 grad_sync="compressed_psum", mesh=mesh)
    tr.run(jax.random.PRNGKey(0))
    ref_losses = [m["loss"] for m in ref.metrics_log]
    dp_losses = [m["loss"] for m in tr.metrics_log]
    np.testing.assert_allclose(dp_losses, ref_losses, atol=5e-2)


def test_trainer_grad_sync_validation(setup):
    cfg, tcfg, dcfg, mesh = setup
    loop = LoopConfig()
    with pytest.raises(ValueError, match="unknown grad_sync"):
        Trainer(cfg, tcfg, dcfg, loop, grad_sync="bogus", mesh=mesh)
    with pytest.raises(ValueError, match="needs a mesh"):
        Trainer(cfg, tcfg, dcfg, loop, grad_sync="psum")
    with pytest.raises(ValueError, match="not both"):
        Trainer(cfg, tcfg, dcfg, loop, grad_sync="psum", mesh=mesh,
                step_fn=lambda s, b: (s, {}))
