"""Per-arch smoke tests + decode consistency + train-step behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ALL_ARCHS, SHAPES, config_names, get_config,
                           reduced, shape_applicable)
from repro.models import model as M
from repro.models.cache import init_caches
from repro.models.layers import split_leaves
from repro.train import train_step as TS


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch, key):
    """Reduced same-family config: one forward, shapes + finiteness."""
    cfg = reduced(get_config(arch))
    params, _ = split_leaves(M.init_model(key, cfg))
    B, S = 2, 64
    if cfg.frontend:
        ins = dict(embeds=jax.random.normal(key, (B, S, cfg.d_model)))
    else:
        ins = dict(tokens=jax.random.randint(key, (B, S), 0, cfg.vocab_size))
    logits, _, aux = jax.jit(
        lambda p, **kw: M.forward(p, cfg, **kw))(params, **ins)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch, key):
    """One reduced train step on CPU: finite loss + param update."""
    cfg = reduced(get_config(arch))
    tcfg = TS.TrainConfig(total_steps=10, warmup_steps=2)
    state, _ = TS.init_state(key, cfg, tcfg)
    B, S = 2, 32
    if cfg.frontend:
        batch = {"embeds": np.random.RandomState(0)
                 .standard_normal((B, S, cfg.d_model)).astype(np.float32),
                 "labels": np.random.RandomState(1)
                 .randint(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    else:
        toks = np.random.RandomState(0).randint(
            0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    step_fn = TS.jit_train_step(cfg, tcfg)
    state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 1
    # warmup lr is 0 at step 0, so check momentum first...
    mu_norm = sum(float(jnp.abs(x.astype(jnp.float32)).sum())
                  for x in jax.tree.leaves(state.opt.mu))
    assert mu_norm > 0
    # ...then params after a second (lr > 0) step
    before = [np.asarray(x, np.float32)
              for x in jax.tree.leaves(state.params)
              if x.dtype in (jnp.float32, jnp.bfloat16)]
    state, metrics = step_fn(state, batch)
    after = [np.asarray(x, np.float32)
             for x in jax.tree.leaves(state.params)
             if x.dtype in (jnp.float32, jnp.bfloat16)]
    delta = sum(float(np.abs(a - b).sum()) for a, b in zip(after, before))
    assert delta > 0 and np.isfinite(float(metrics["loss"]))


DECODE_ARCHS = ["yi-6b", "mixtral-8x7b", "mamba2-2.7b", "recurrentgemma-2b",
                "qwen1.5-0.5b", "qwen2-vl-2b", "minitron-8b", "qwen2-72b",
                "arctic-480b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch, key):
    cfg = reduced(get_config(arch))
    params, _ = split_leaves(M.init_model(key, cfg))
    B, S = 2, 48
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full, _, _ = M.forward(params, cfg, tokens=toks)
    caches = init_caches(cfg, B, max_len=64)
    _, caches2, _ = M.forward(params, cfg, tokens=toks[:, :S - 1],
                              caches=caches, pos=0)
    lg_dec, _, _ = M.forward(params, cfg, tokens=toks[:, S - 1:],
                             caches=caches2, pos=jnp.int32(S - 1))
    err = np.abs(np.asarray(lg_dec[:, 0]) - np.asarray(logits_full[:, -1])).max()
    scale = max(float(np.abs(np.asarray(logits_full[:, -1])).max()), 1.0)
    assert err < 5e-4 * scale


def test_encoder_only_has_no_decode():
    cfg = get_config("hubert-xlarge")
    ok, why = shape_applicable(cfg, SHAPES["decode_32k"])
    assert not ok and "encoder-only" in why


def test_long_context_applicability():
    assert shape_applicable(get_config("mamba2-2.7b"), SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("mixtral-8x7b"), SHAPES["long_500k"])[0]
    assert not shape_applicable(get_config("qwen2-72b"), SHAPES["long_500k"])[0]


def test_full_configs_match_assignment():
    """The registered configs carry the assigned hyperparameters."""
    spec = {
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }
    for arch, (L, d, H, Hkv, f, V) in spec.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, H, Hkv, f, V), (arch, got)
    assert get_config("mixtral-8x7b").num_experts == 8
    assert get_config("arctic-480b").num_experts == 128
    assert get_config("mamba2-2.7b").ssm_state == 128
    assert get_config("hubert-xlarge").causal is False


def test_loss_decreases_quickly():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    tcfg = TS.TrainConfig(base_lr=1e-3, warmup_steps=2, total_steps=20)
    state, _ = TS.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = TS.jit_train_step(cfg, tcfg)
    from repro.data.pipeline import DataConfig, make_batch

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    losses = []
    for i in range(10):
        state, m = step(state, make_batch(dcfg, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
