"""METG harness unit + property tests (synthetic timing model)."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.metg import (SweepPoint, compute_metg, geometric_iterations)


def synthetic_points(overhead_s: float, work_per_iter_s: float,
                     iters_list, num_tasks=256, flops_per_iter=2048.0):
    """wall = tasks * (overhead + work) — the paper's overhead model."""
    pts = []
    for it in iters_list:
        wall = num_tasks * (overhead_s + it * work_per_iter_s)
        pts.append(SweepPoint(
            iterations=it, wall_time=wall, num_tasks=num_tasks,
            useful_work=num_tasks * it * flops_per_iter,
            granularity=wall / num_tasks))
    return pts


def test_metg_crossing_matches_analytic():
    """With wall = tasks*(o + w*i), efficiency hits 50% exactly when
    w*i == o, i.e. granularity = 2*o."""
    o, w = 1e-5, 1e-8
    pts = synthetic_points(o, w, geometric_iterations(1 << 20, 1, 2.0))
    res = compute_metg(pts, threshold=0.5)
    assert res.metg is not None
    assert res.metg == pytest.approx(2 * o, rel=0.15)


def test_metg_none_when_never_efficient():
    # overhead so large that efficiency never reaches 50% of its own peak?
    # peak is self-normalized, so we pin peak_rate externally.
    o, w = 1e-3, 1e-9
    pts = synthetic_points(o, w, [1024, 256, 64, 16, 4, 1])
    res = compute_metg(pts, threshold=0.5, peak_rate=2048 / 1e-9 * 2)
    assert res.metg is None


def test_metg_threshold_parameter():
    o, w = 1e-5, 1e-8
    pts = synthetic_points(o, w, geometric_iterations(1 << 20, 1, 2.0))
    m90 = compute_metg(pts, threshold=0.9).metg
    m50 = compute_metg(pts, threshold=0.5).metg
    assert m90 > m50  # higher efficiency demands coarser tasks


@settings(max_examples=50, deadline=None)
@given(hi=st.integers(2, 1 << 22), lo=st.integers(1, 64),
       factor=st.floats(1.5, 8.0))
def test_geometric_iterations_properties(hi, lo, factor):
    if lo > hi:
        lo, hi = hi, lo
    xs = geometric_iterations(hi, lo, factor)
    assert xs[0] == hi and xs[-1] == lo
    assert all(a > b for a, b in zip(xs, xs[1:]))  # strictly decreasing
    assert all(lo <= x <= hi for x in xs)


def test_metg_robust_to_nonmonotone_noise():
    o, w = 1e-5, 1e-8
    pts = synthetic_points(o, w, geometric_iterations(1 << 18, 1, 2.0))
    # inject noise: make one mid point slightly slow
    pts[3].wall_time *= 1.12
    pts[3].granularity *= 1.12
    res = compute_metg(pts, threshold=0.5)
    assert res.metg == pytest.approx(2 * o, rel=0.35)
