"""repro.bench: scenarios, timers, deterministic METG, artifacts.

The fake-clock (``SyntheticTimer``) tests assert exact METG crossovers
against the closed-form efficiency curve — no wall-clock measurement, so
nothing here is timing-flaky in CI.
"""
import json
import os

import pytest

from repro.bench import (DryRunTimer, ScenarioSpec, SweepControls,
                         SyntheticTimer, Timer, WallClockTimer,
                         bench_artifact, read_bench_json, run_scenario,
                         validate_artifact, write_bench_json)
from repro.bench.scenario import (SMOKE_HEIGHT, SMOKE_ITERATIONS_HI,
                                  SMOKE_N_POINTS)
from repro.bench.timers import pick_sample


# ---------------------------------------------------------------- scenarios
def test_scenario_compiles_to_graphs():
    spec = ScenarioSpec(name="s", pattern="nearest", width=6, height=9,
                        ngraphs=3, output_bytes=64, imbalance=0.5,
                        graph_kw=(("radix", 5),))
    graphs = spec.graphs(iterations=7)
    assert len(graphs) == 3
    g = graphs[0]
    assert (g.width, g.height, g.pattern) == (6, 9, "nearest")
    assert g.kernel.iterations == 7 and g.kernel.imbalance == 0.5
    assert g.output_bytes == 64
    assert dict(g.pattern_params)["radix"] == 5


def test_scenario_requires_name_and_graphs():
    with pytest.raises(ValueError):
        ScenarioSpec(name="")
    with pytest.raises(ValueError):
        ScenarioSpec(name="x", ngraphs=0)


def test_sweep_controls_validate_eagerly():
    """Bad controls fail at spec construction, not deep inside the sweep."""
    with pytest.raises(ValueError):
        SweepControls(iterations_hi=0)
    with pytest.raises(ValueError):
        SweepControls(iterations_hi=4, iterations_lo=8)
    with pytest.raises(ValueError):
        SweepControls(iterations_lo=0)
    with pytest.raises(ValueError):
        SweepControls(n_points=0)
    with pytest.raises(ValueError):
        SweepControls(schedule=())
    with pytest.raises(ValueError):
        SweepControls(schedule=(16, 0))
    # smoke resolution must cap the floor along with the ceiling
    # (regression: replace() re-validates hi >= lo)
    r = SweepControls(iterations_hi=4096, iterations_lo=128,
                      smoke=True).resolved()
    assert r.iterations_hi >= r.iterations_lo


def test_sweep_schedule_geometric_and_explicit():
    c = SweepControls(iterations_hi=4096, n_points=6)
    sched = c.iteration_schedule()
    assert len(sched) == 6 and sched[0] == 4096
    assert all(a > b for a, b in zip(sched, sched[1:]))
    assert SweepControls(schedule=(100, 10, 1)).iteration_schedule() == \
        [100, 10, 1]


def test_smoke_is_a_spec_parameter_not_a_global():
    spec = ScenarioSpec(name="s", height=32,
                        sweep=SweepControls(iterations_hi=65536, n_points=9,
                                            repeats=5, smoke=True))
    r = spec.resolved()
    assert r.height == SMOKE_HEIGHT
    assert r.sweep.iterations_hi == SMOKE_ITERATIONS_HI
    assert r.sweep.n_points == SMOKE_N_POINTS
    assert r.sweep.repeats == 1
    # explicit schedules are capped and truncated too
    c = SweepControls(schedule=(65536, 4096, 64, 16, 4, 1), smoke=True)
    sched = c.iteration_schedule()
    assert len(sched) <= SMOKE_N_POINTS
    assert max(sched) <= SMOKE_ITERATIONS_HI
    # the original spec is untouched (frozen, declarative)
    assert spec.height == 32 and spec.sweep.iterations_hi == 65536


# ------------------------------------------------------- deterministic METG
def test_fake_clock_metg_finds_analytic_crossover():
    """wall = tasks*(o + w*i) crosses 50 % efficiency at granularity 2*o."""
    o, w = 1e-5, 1e-8
    spec = ScenarioSpec(name="fake", backend="unused-by-synthetic-timer",
                        pattern="trivial", width=8, height=32,
                        sweep=SweepControls(iterations_hi=1 << 20,
                                            n_points=21))
    res = run_scenario(spec, timer=SyntheticTimer(
        overhead_per_task=o, seconds_per_iteration=w))
    assert res.timer == "synthetic"
    assert res.metg_s == pytest.approx(2 * o, rel=0.15)


def test_fake_clock_metg_threshold_ordering():
    spec = ScenarioSpec(name="fake", pattern="trivial", width=8, height=32,
                        sweep=SweepControls(iterations_hi=1 << 20,
                                            n_points=21, threshold=0.9))
    timer = SyntheticTimer(overhead_per_task=1e-5, seconds_per_iteration=1e-8)
    m90 = run_scenario(spec, timer=timer).metg_s
    spec50 = ScenarioSpec(name="fake", pattern="trivial", width=8, height=32,
                          sweep=SweepControls(iterations_hi=1 << 20,
                                              n_points=21, threshold=0.5))
    m50 = run_scenario(spec50, timer=timer).metg_s
    assert m90 > m50  # higher efficiency demands coarser tasks


def test_fake_clock_metg_none_when_pinned_peak_unreachable():
    spec = ScenarioSpec(name="fake", pattern="trivial", width=8, height=16,
                        sweep=SweepControls(iterations_hi=1024, n_points=6))
    timer = SyntheticTimer(overhead_per_task=1e-3,
                           seconds_per_iteration=1e-9)
    work_rate = spec.graph(1).kernel.flops_per_task / 1  # flops per iter
    res = run_scenario(spec, timer=timer,
                       peak_rate=work_rate / 1e-9 * 2)  # impossible peak
    assert res.metg_s is None


def test_fake_clock_is_imbalance_aware():
    timer = SyntheticTimer(overhead_per_task=0.0, seconds_per_iteration=1e-6)
    spec = ScenarioSpec(name="fake", pattern="trivial", width=4, height=4)
    balanced = timer.measure("any", spec.graphs(100))
    imb = ScenarioSpec(name="fake", pattern="trivial", width=4, height=4,
                       imbalance=1.0)
    imbalanced = timer.measure("any", imb.graphs(100))
    assert imbalanced < balanced  # shorter tasks -> less synthetic work


# ------------------------------------------------------------------ timers
def test_timer_protocol_runtime_checkable():
    assert isinstance(WallClockTimer(), Timer)
    assert isinstance(SyntheticTimer(), Timer)
    assert isinstance(DryRunTimer(), Timer)


def test_custom_timer_flows_through_to_artifact(tmp_path):
    """Timer is an open protocol: a user-defined timer runs a scenario and
    its artifact validates (the artifact layer must not whitelist names)."""

    class TickTimer:
        name = "tick"

        def config(self):
            return {"tick_s": 1e-3}

        def measure(self, backend_name, graphs):
            return 1e-3 * sum(g.num_tasks for g in graphs)

    spec = ScenarioSpec(name="custom.timer", pattern="trivial",
                        width=4, height=4,
                        sweep=SweepControls(iterations_hi=16, n_points=3))
    res = run_scenario(spec, timer=TickTimer())
    doc = read_bench_json(write_bench_json(res, str(tmp_path)))
    assert doc["timer"] == "tick"
    assert doc["timer_config"] == {"tick_s": 1e-3}


def test_pick_sample_percentiles():
    samples = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert pick_sample(samples, 0.0) == 1.0      # best-of-N
    assert pick_sample(samples, 50.0) == 3.0     # median
    assert pick_sample(samples, 100.0) == 5.0    # worst case
    with pytest.raises(ValueError):
        pick_sample([], 0.0)


def test_wallclock_timer_measures_real_run():
    spec = ScenarioSpec(name="wc", backend="xla-scan", width=4, height=6)
    t = WallClockTimer(warmup=1, repeats=2)
    wall = t.measure(spec.backend, spec.graphs(4))
    assert wall > 0


def test_dryrun_timer_models_compiled_cost():
    spec = ScenarioSpec(name="dr", backend="xla-scan", width=4, height=6)
    t = DryRunTimer()
    small = t.measure(spec.backend, spec.graphs(4))
    big = t.measure(spec.backend, spec.graphs(4096))
    assert 0 < small < big  # more kernel iterations -> more modeled time


def test_dryrun_timer_rejects_hostonly_backend():
    spec = ScenarioSpec(name="dr", backend="host-dynamic", width=4, height=4)
    with pytest.raises(ValueError, match="compiled HLO"):
        DryRunTimer().measure(spec.backend, spec.graphs(2))


# --------------------------------------------------------------- artifacts
def _tiny_result():
    spec = ScenarioSpec(name="artifact/check v1", pattern="trivial",
                        width=4, height=8, ngraphs=2,
                        sweep=SweepControls(iterations_hi=256, n_points=5))
    return run_scenario(spec, timer=SyntheticTimer())


def test_artifact_schema_roundtrip(tmp_path):
    res = _tiny_result()
    path = write_bench_json(res, str(tmp_path))
    assert os.path.basename(path) == "BENCH_artifact-check-v1.json"
    doc = read_bench_json(path)  # validates
    assert doc["schema"] == 1 and doc["kind"] == "metg_sweep"
    assert doc["timer"] == "synthetic"
    # the timer's actual parameters are recorded (authoritative over
    # spec.sweep when a timer override was supplied)
    assert doc["timer_config"]["overhead_per_task"] == \
        SyntheticTimer().overhead_per_task
    assert doc["scenario"]["ngraphs"] == 2
    assert doc["points"][0]["iterations"] == 256
    assert doc["metg_s"] == pytest.approx(res.metg_s)
    effs = [p["efficiency"] for p in doc["points"]]
    assert max(effs) == pytest.approx(1.0)


def test_artifact_validation_rejects_corruption():
    doc = bench_artifact(_tiny_result())
    validate_artifact(doc)
    for breakage in (
        {"schema": 99},
        {"kind": "nope"},
        {"timer": ""},
        {"timer_config": "not-a-dict"},
        {"points": []},
        {"scenario": {}},
        {"threshold": True},  # bools must not pass as numerics
        {"peak_rate": False},
    ):
        bad = {**doc, **breakage}
        with pytest.raises(ValueError):
            validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    del bad["points"][0]["efficiency"]
    with pytest.raises(ValueError):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    bad["points"][0]["efficiency"] = False  # bool-as-numeric corruption
    with pytest.raises(ValueError):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    del bad["metg_s"]  # missing key != legal null
    with pytest.raises(ValueError):
        validate_artifact(bad)
    ok = json.loads(json.dumps(doc))
    ok["metg_s"] = None  # no crossing is a valid result
    validate_artifact(ok)


# ------------------------------------------------- benchmarks CLI contract
def test_benchmarks_smoke_emits_valid_artifacts(tmp_path, capsys):
    """`python -m benchmarks.run --smoke` writes >= 1 schema-valid
    BENCH_*.json (the acceptance contract for the CI artifact upload)."""
    from benchmarks.run import main

    main(["--smoke", "--only", "bench_scaling",
          "--artifacts", str(tmp_path)])
    out = capsys.readouterr().out
    assert "name,us_per_call,derived" in out
    files = sorted(p for p in os.listdir(tmp_path)
                   if p.startswith("BENCH_") and p.endswith(".json"))
    assert files, "no BENCH_*.json emitted"
    for f in files:
        doc = read_bench_json(os.path.join(tmp_path, f))
        assert doc["scenario"]["sweep"]["smoke"] is True


def test_bench_context_threads_smoke_and_artifacts(tmp_path):
    from benchmarks.common import BenchContext, metg_for

    ctx = BenchContext(smoke=True, artifacts_dir=str(tmp_path),
                       timer=SyntheticTimer())
    res = metg_for(ctx, "xla-scan", "stencil", name="ctx.check",
                   iterations_hi=4096, n_points=6)
    assert res.peak_rate > 0
    assert len(res.points) <= SMOKE_N_POINTS  # smoke reached the sweep
    assert ctx.written and ctx.written[0].endswith("BENCH_ctx.check.json")
    read_bench_json(ctx.written[0])


def test_bench_context_rejects_slug_collision(tmp_path):
    """Distinct scenario names that slugify identically must not silently
    clobber each other's artifacts within one run — and the guard fires
    *before* the earlier artifact is overwritten."""
    from benchmarks.common import BenchContext, metg_for

    ctx = BenchContext(smoke=True, artifacts_dir=str(tmp_path),
                       timer=SyntheticTimer())
    metg_for(ctx, "xla-scan", "trivial", name="clash x1")
    with pytest.raises(ValueError, match="distinct slugs"):
        metg_for(ctx, "xla-scan", "trivial", name="clash-x1")
    # the first scenario's artifact survived intact
    assert read_bench_json(ctx.written[0])["scenario"]["name"] == "clash x1"
