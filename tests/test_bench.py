"""repro.bench: scenarios, timers, deterministic METG, artifacts.

The fake-clock (``SyntheticTimer``) tests assert exact METG crossovers
against the closed-form efficiency curve — no wall-clock measurement, so
nothing here is timing-flaky in CI.
"""
import json
import os

import pytest

from repro.bench import (DryRunTimer, ScenarioSpec, SweepControls,
                         SyntheticTimer, Timer, WallClockTimer,
                         bench_artifact, read_bench_json, run_scenario,
                         validate_artifact, write_bench_json)
from repro.bench.scenario import (SMOKE_HEIGHT, SMOKE_ITERATIONS_HI,
                                  SMOKE_N_POINTS)
from repro.bench.timers import pick_sample


# ---------------------------------------------------------------- scenarios
def test_scenario_compiles_to_graphs():
    spec = ScenarioSpec(name="s", pattern="nearest", width=6, height=9,
                        ngraphs=3, output_bytes=64, imbalance=0.5,
                        graph_kw=(("radix", 5),))
    graphs = spec.graphs(iterations=7)
    assert len(graphs) == 3
    g = graphs[0]
    assert (g.width, g.height, g.pattern) == (6, 9, "nearest")
    assert g.kernel.iterations == 7 and g.kernel.imbalance == 0.5
    assert g.output_bytes == 64
    assert dict(g.pattern_params)["radix"] == 5


def test_scenario_requires_name_and_graphs():
    with pytest.raises(ValueError):
        ScenarioSpec(name="")
    with pytest.raises(ValueError):
        ScenarioSpec(name="x", ngraphs=0)


def test_sweep_controls_validate_eagerly():
    """Bad controls fail at spec construction, not deep inside the sweep."""
    with pytest.raises(ValueError):
        SweepControls(iterations_hi=0)
    with pytest.raises(ValueError):
        SweepControls(iterations_hi=4, iterations_lo=8)
    with pytest.raises(ValueError):
        SweepControls(iterations_lo=0)
    with pytest.raises(ValueError):
        SweepControls(n_points=0)
    with pytest.raises(ValueError):
        SweepControls(schedule=())
    with pytest.raises(ValueError):
        SweepControls(schedule=(16, 0))
    # smoke resolution must cap the floor along with the ceiling
    # (regression: replace() re-validates hi >= lo)
    r = SweepControls(iterations_hi=4096, iterations_lo=128,
                      smoke=True).resolved()
    assert r.iterations_hi >= r.iterations_lo


def test_sweep_schedule_geometric_and_explicit():
    c = SweepControls(iterations_hi=4096, n_points=6)
    sched = c.iteration_schedule()
    assert len(sched) == 6 and sched[0] == 4096
    assert all(a > b for a, b in zip(sched, sched[1:]))
    assert SweepControls(schedule=(100, 10, 1)).iteration_schedule() == \
        [100, 10, 1]


def test_smoke_is_a_spec_parameter_not_a_global():
    spec = ScenarioSpec(name="s", height=32,
                        sweep=SweepControls(iterations_hi=65536, n_points=9,
                                            repeats=5, smoke=True))
    r = spec.resolved()
    assert r.height == SMOKE_HEIGHT
    assert r.sweep.iterations_hi == SMOKE_ITERATIONS_HI
    assert r.sweep.n_points == SMOKE_N_POINTS
    assert r.sweep.repeats == 1
    # explicit schedules are capped and truncated too
    c = SweepControls(schedule=(65536, 4096, 64, 16, 4, 1), smoke=True)
    sched = c.iteration_schedule()
    assert len(sched) <= SMOKE_N_POINTS
    assert max(sched) <= SMOKE_ITERATIONS_HI
    # the original spec is untouched (frozen, declarative)
    assert spec.height == 32 and spec.sweep.iterations_hi == 65536


# ------------------------------------------------------- deterministic METG
def test_fake_clock_metg_finds_analytic_crossover():
    """wall = tasks*(o + w*i) crosses 50 % efficiency at granularity 2*o."""
    o, w = 1e-5, 1e-8
    spec = ScenarioSpec(name="fake", backend="unused-by-synthetic-timer",
                        pattern="trivial", width=8, height=32,
                        sweep=SweepControls(iterations_hi=1 << 20,
                                            n_points=21))
    res = run_scenario(spec, timer=SyntheticTimer(
        overhead_per_task=o, seconds_per_iteration=w))
    assert res.timer == "synthetic"
    assert res.metg_s == pytest.approx(2 * o, rel=0.15)


def test_fake_clock_metg_threshold_ordering():
    spec = ScenarioSpec(name="fake", pattern="trivial", width=8, height=32,
                        sweep=SweepControls(iterations_hi=1 << 20,
                                            n_points=21, threshold=0.9))
    timer = SyntheticTimer(overhead_per_task=1e-5, seconds_per_iteration=1e-8)
    m90 = run_scenario(spec, timer=timer).metg_s
    spec50 = ScenarioSpec(name="fake", pattern="trivial", width=8, height=32,
                          sweep=SweepControls(iterations_hi=1 << 20,
                                              n_points=21, threshold=0.5))
    m50 = run_scenario(spec50, timer=timer).metg_s
    assert m90 > m50  # higher efficiency demands coarser tasks


def test_fake_clock_metg_none_when_pinned_peak_unreachable():
    spec = ScenarioSpec(name="fake", pattern="trivial", width=8, height=16,
                        sweep=SweepControls(iterations_hi=1024, n_points=6))
    timer = SyntheticTimer(overhead_per_task=1e-3,
                           seconds_per_iteration=1e-9)
    work_rate = spec.graph(1).kernel.flops_per_task / 1  # flops per iter
    res = run_scenario(spec, timer=timer,
                       peak_rate=work_rate / 1e-9 * 2)  # impossible peak
    assert res.metg_s is None


def test_fake_clock_is_imbalance_aware():
    timer = SyntheticTimer(overhead_per_task=0.0, seconds_per_iteration=1e-6)
    spec = ScenarioSpec(name="fake", pattern="trivial", width=4, height=4)
    balanced = timer.measure("any", spec.graphs(100))
    imb = ScenarioSpec(name="fake", pattern="trivial", width=4, height=4,
                       imbalance=1.0)
    imbalanced = timer.measure("any", imb.graphs(100))
    assert imbalanced < balanced  # shorter tasks -> less synthetic work


# ------------------------------------------------------------------ timers
def test_timer_protocol_runtime_checkable():
    assert isinstance(WallClockTimer(), Timer)
    assert isinstance(SyntheticTimer(), Timer)
    assert isinstance(DryRunTimer(), Timer)


def test_custom_timer_flows_through_to_artifact(tmp_path):
    """Timer is an open protocol: a user-defined timer runs a scenario and
    its artifact validates (the artifact layer must not whitelist names)."""

    class TickTimer:
        name = "tick"

        def config(self):
            return {"tick_s": 1e-3}

        def measure(self, backend_name, graphs):
            return 1e-3 * sum(g.num_tasks for g in graphs)

    spec = ScenarioSpec(name="custom.timer", pattern="trivial",
                        width=4, height=4,
                        sweep=SweepControls(iterations_hi=16, n_points=3))
    res = run_scenario(spec, timer=TickTimer())
    doc = read_bench_json(write_bench_json(res, str(tmp_path)))
    assert doc["timer"] == "tick"
    assert doc["timer_config"] == {"tick_s": 1e-3}


def test_pick_sample_percentiles():
    samples = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert pick_sample(samples, 0.0) == 1.0      # best-of-N
    assert pick_sample(samples, 50.0) == 3.0     # median
    assert pick_sample(samples, 100.0) == 5.0    # worst case
    with pytest.raises(ValueError):
        pick_sample([], 0.0)


def test_wallclock_timer_measures_real_run():
    spec = ScenarioSpec(name="wc", backend="xla-scan", width=4, height=6)
    t = WallClockTimer(warmup=1, repeats=2)
    wall = t.measure(spec.backend, spec.graphs(4))
    assert wall > 0


def test_dryrun_timer_models_compiled_cost():
    spec = ScenarioSpec(name="dr", backend="xla-scan", width=4, height=6)
    t = DryRunTimer()
    small = t.measure(spec.backend, spec.graphs(4))
    big = t.measure(spec.backend, spec.graphs(4096))
    assert 0 < small < big  # more kernel iterations -> more modeled time


def test_dryrun_timer_rejects_hostonly_backend():
    spec = ScenarioSpec(name="dr", backend="host-dynamic", width=4, height=4)
    with pytest.raises(ValueError, match="compiled HLO"):
        DryRunTimer().measure(spec.backend, spec.graphs(2))


# --------------------------------------------------------------- artifacts
def _tiny_result():
    spec = ScenarioSpec(name="artifact/check v1", pattern="trivial",
                        width=4, height=8, ngraphs=2,
                        sweep=SweepControls(iterations_hi=256, n_points=5))
    return run_scenario(spec, timer=SyntheticTimer())


def test_artifact_schema_roundtrip(tmp_path):
    res = _tiny_result()
    path = write_bench_json(res, str(tmp_path))
    assert os.path.basename(path) == "BENCH_artifact-check-v1.json"
    doc = read_bench_json(path)  # validates
    assert doc["schema"] == 1 and doc["kind"] == "metg_sweep"
    assert doc["timer"] == "synthetic"
    # the timer's actual parameters are recorded (authoritative over
    # spec.sweep when a timer override was supplied)
    assert doc["timer_config"]["overhead_per_task"] == \
        SyntheticTimer().overhead_per_task
    assert doc["scenario"]["ngraphs"] == 2
    assert doc["points"][0]["iterations"] == 256
    assert doc["metg_s"] == pytest.approx(res.metg_s)
    effs = [p["efficiency"] for p in doc["points"]]
    assert max(effs) == pytest.approx(1.0)


def test_artifact_validation_names_the_offending_key():
    """Every negative path raises with a message naming what broke:
    wrong-typed fields, missing keys, unknown schema version."""
    doc = bench_artifact(_tiny_result())
    validate_artifact(doc)

    def breaks(message, **changes):
        with pytest.raises(ValueError, match=message):
            validate_artifact({**doc, **changes})

    # unknown schema version / kind
    breaks("schema must be 1", schema=2)
    breaks("schema must be 1", schema="1")
    breaks("unknown kind", kind="not_a_sweep")
    # wrong-typed top-level fields
    breaks("timer must be a non-empty string", timer=7)
    breaks("timer_config", timer_config=[])
    breaks("threshold", threshold="0.5")
    breaks("peak_rate", peak_rate=None)
    breaks("metg_s", metg_s="fast")
    # missing keys
    for key in ("schema", "kind", "timer", "scenario", "points"):
        stripped = {k: v for k, v in doc.items() if k != key}
        with pytest.raises(ValueError):
            validate_artifact(stripped)
    with pytest.raises(ValueError, match="metg_s"):
        validate_artifact({k: v for k, v in doc.items() if k != "metg_s"})
    # scenario / point fields: wrong type and missing, each named
    bad = json.loads(json.dumps(doc))
    bad["scenario"]["width"] = "8"
    with pytest.raises(ValueError, match="scenario.width"):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    del bad["scenario"]["backend"]
    with pytest.raises(ValueError, match="scenario.backend"):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    bad["scenario"]["name"] = ""
    with pytest.raises(ValueError, match="scenario.name"):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    bad["points"][1]["rate"] = []
    with pytest.raises(ValueError, match=r"points\[1\].rate"):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    del bad["points"][0]["num_tasks"]
    with pytest.raises(ValueError, match=r"points\[0\].num_tasks"):
        validate_artifact(bad)


def test_read_bench_json_rejects_truncated_and_garbage(tmp_path):
    """Corrupt files fail as ValueError naming the path — the same
    exception type as schema violations, so the compare gate and CI catch
    both identically."""
    path = write_bench_json(_tiny_result(), str(tmp_path))
    read_bench_json(path)  # sanity: intact file round-trips
    # truncated mid-document
    text = open(path).read()
    trunc = os.path.join(tmp_path, "BENCH_trunc.json")
    with open(trunc, "w") as f:
        f.write(text[: len(text) // 2])
    with pytest.raises(ValueError, match="not valid JSON"):
        read_bench_json(trunc)
    # outright garbage
    garbage = os.path.join(tmp_path, "BENCH_garbage.json")
    with open(garbage, "w") as f:
        f.write("\x00\x01not json at all{{{")
    with pytest.raises(ValueError, match="not valid JSON"):
        read_bench_json(garbage)
    # valid JSON, wrong shape (schema layer takes over)
    shapeless = os.path.join(tmp_path, "BENCH_shapeless.json")
    with open(shapeless, "w") as f:
        json.dump(["not", "an", "object"], f)
    with pytest.raises(ValueError, match="not an object"):
        read_bench_json(shapeless)


def test_artifact_validation_rejects_corruption():
    doc = bench_artifact(_tiny_result())
    validate_artifact(doc)
    for breakage in (
        {"schema": 99},
        {"kind": "nope"},
        {"timer": ""},
        {"timer_config": "not-a-dict"},
        {"points": []},
        {"scenario": {}},
        {"threshold": True},  # bools must not pass as numerics
        {"peak_rate": False},
    ):
        bad = {**doc, **breakage}
        with pytest.raises(ValueError):
            validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    del bad["points"][0]["efficiency"]
    with pytest.raises(ValueError):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    bad["points"][0]["efficiency"] = False  # bool-as-numeric corruption
    with pytest.raises(ValueError):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    del bad["metg_s"]  # missing key != legal null
    with pytest.raises(ValueError):
        validate_artifact(bad)
    ok = json.loads(json.dumps(doc))
    ok["metg_s"] = None  # no crossing is a valid result
    validate_artifact(ok)


# ------------------------------------------------ bench-regression compare
def _doc(scale=1.0, name="artifact/check v1"):
    doc = bench_artifact(_tiny_result())
    doc = json.loads(json.dumps(doc))  # deep copy
    doc["scenario"]["name"] = name
    for p in doc["points"]:
        p["wall_time_s"] *= scale
    if doc["metg_s"] is not None:
        doc["metg_s"] *= scale
    return doc


def test_compare_identical_artifacts_pass():
    from repro.bench import compare_artifacts

    res = compare_artifacts(_doc(), _doc(), rel_threshold=0.01)
    assert res.ok
    assert res.metg_rel_delta == pytest.approx(0.0)
    assert res.points and all(p.rel_delta == pytest.approx(0.0)
                              for p in res.points)


def test_compare_flags_metg_and_point_regressions():
    from repro.bench import compare_artifacts

    res = compare_artifacts(_doc(), _doc(scale=2.0), rel_threshold=0.25)
    assert not res.ok
    assert any("METG" in r for r in res.regressions)
    assert any("point iterations=" in r for r in res.regressions)
    # a 2x speedup is never a regression
    assert compare_artifacts(_doc(), _doc(scale=0.5),
                             rel_threshold=0.25).ok
    # within threshold passes
    assert compare_artifacts(_doc(), _doc(scale=1.1),
                             rel_threshold=0.25).ok


def test_compare_metg_lost_crossing_regresses():
    from repro.bench import compare_artifacts

    cur = _doc()
    cur["metg_s"] = None
    res = compare_artifacts(_doc(), cur, rel_threshold=0.25)
    assert any("no longer crosses" in r for r in res.regressions)
    # baseline never crossed: nothing to gate on
    base = _doc()
    base["metg_s"] = None
    assert compare_artifacts(base, _doc(), rel_threshold=0.25).ok


def test_compare_rejects_identity_mismatch_and_missing_points():
    from repro.bench import compare_artifacts

    other = _doc(name="something else")
    res = compare_artifacts(_doc(), other, rel_threshold=0.25)
    assert any("scenario.name changed" in r for r in res.regressions)
    cur = _doc()
    cur["points"] = cur["points"][:-1]
    res = compare_artifacts(_doc(), cur, rel_threshold=0.25)
    assert any("missing" in r for r in res.regressions)
    with pytest.raises(ValueError, match="rel_threshold"):
        compare_artifacts(_doc(), _doc(), rel_threshold=0.0)
    # wall-clock vs fake-clock times are not comparable: refuse, even
    # when the numbers would happen to sit inside the threshold
    cur = _doc()
    cur["timer"] = "wallclock"
    res = compare_artifacts(_doc(), cur, rel_threshold=0.25)
    assert any("timer changed" in r for r in res.regressions)


def test_compare_zero_baseline_is_identity_mismatch_not_inf():
    """Bugfix pin: a 0.0 baseline point against a nonzero current used to
    produce an inf relative delta in the report.  It is an identity
    mismatch (the artifacts disagree about what was measured) and must be
    reported as a named error; both-zero compares equal."""
    from repro.bench import compare_artifacts
    from repro.bench.compare import ZeroBaselineError, _rel_delta

    with pytest.raises(ZeroBaselineError, match="identity mismatch"):
        _rel_delta(0.0, 1.0)
    assert _rel_delta(0.0, 0.0) == 0.0
    assert _rel_delta(2.0, 1.0) == -0.5
    # through the artifact differ: the point is a regression with the
    # named message, never an inf in the summary
    base = _doc()
    base["points"][0]["wall_time_s"] = 0.0
    res = compare_artifacts(base, _doc(), rel_threshold=0.25)
    assert not res.ok
    assert any("identity mismatch" in r for r in res.regressions)
    assert "inf" not in res.summary()
    # a zeroed METG baseline takes the same path
    mbase = _doc()
    mbase["metg_s"] = 0.0
    res = compare_artifacts(mbase, _doc(), rel_threshold=0.25)
    assert not res.ok and any("METG" in r for r in res.regressions)


def test_compare_dirs_reports_new_in_current_scenarios(tmp_path):
    """Bugfix pin: a scenario present only in the current run used to be
    silently invisible in the gate summary.  It is non-fatal (ok=True)
    but must appear, with the commit-a-snapshot hint."""
    from repro.bench import compare_dirs, format_report

    base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
    spec = ScenarioSpec(name="newgate.old", pattern="trivial", width=4,
                        height=8)
    write_bench_json(run_scenario(spec, timer=SyntheticTimer()),
                     str(base_dir))
    write_bench_json(run_scenario(spec, timer=SyntheticTimer()),
                     str(cur_dir))
    spec2 = ScenarioSpec(name="newgate.fresh", pattern="trivial", width=4,
                         height=8)
    write_bench_json(run_scenario(spec2, timer=SyntheticTimer()),
                     str(cur_dir))
    results = compare_dirs(str(base_dir), str(cur_dir))
    assert len(results) == 2 and all(r.ok for r in results)
    report = format_report(results)
    assert "new in current run; no baseline yet" in report
    assert "commit a snapshot" in report
    # family scoping applies to new-in-current too: a filtered family's
    # new artifact is not reported
    results = compare_dirs(str(base_dir), str(cur_dir),
                           families={"nosuchfamily"})
    assert not any("new in current" in (r.note or "") for r in results)


def test_compare_canonicalizes_backend_spec_key_order():
    """A baseline written with reordered backend-spec options is the SAME
    scenario: the differ must compare canonically, not raw-text — a
    key-reordered baseline tripping 'scenario.backend changed' would
    poison the whole --baseline gate for that scenario."""
    from repro.bench import compare_artifacts

    base, cur = _doc(), _doc()
    base["scenario"]["backend"] = \
        "shardmap-csp[comm_overlap=True,comm=onesided]"
    cur["scenario"]["backend"] = \
        "shardmap-csp[comm=onesided,comm_overlap=True]"
    assert compare_artifacts(base, cur, rel_threshold=0.25).ok
    # a genuinely different backend still refuses
    cur["scenario"]["backend"] = "shardmap-csp[comm=onesided]"
    res = compare_artifacts(base, cur, rel_threshold=0.25)
    assert any("scenario.backend changed" in r for r in res.regressions)
    # unparseable baseline specs fall back to raw-text comparison (a
    # visible identity mismatch, not a crash)
    base["scenario"]["backend"] = "garbage[[["
    cur["scenario"]["backend"] = "garbage[[["
    assert compare_artifacts(base, cur, rel_threshold=0.25).ok


def test_artifact_records_canonical_backend_spec():
    """bench_artifact writes the canonical spec, so artifact identity
    never depends on how the scenario author ordered the options."""
    spec = ScenarioSpec(
        name="artifact/canon v1", pattern="trivial", width=4, height=8,
        backend="shardmap-csp[comm_overlap=False,comm=onesided]",
        sweep=SweepControls(iterations_hi=64, n_points=2))
    doc = bench_artifact(run_scenario(spec, timer=SyntheticTimer()))
    assert doc["scenario"]["backend"] == \
        "shardmap-csp[comm=onesided,comm_overlap=False]"


def test_artifact_validation_rejects_nonfinite_numbers():
    """NaN/inf in any numeric field is corruption (e.g. a degenerate
    study division leaking through) and must fail the schema check, not
    the CI gate arithmetic downstream."""
    doc = bench_artifact(_tiny_result())
    validate_artifact(doc)
    for breakage in ({"threshold": float("nan")},
                     {"peak_rate": float("inf")},
                     {"metg_s": float("-inf")}):
        with pytest.raises(ValueError):
            validate_artifact({**doc, **breakage})
    bad = json.loads(json.dumps(doc))
    bad["points"][0]["wall_time_s"] = float("nan")
    with pytest.raises(ValueError, match="wall_time_s"):
        validate_artifact(bad)
    bad = json.loads(json.dumps(doc))
    bad["points"][0]["efficiency"] = float("inf")
    with pytest.raises(ValueError, match="efficiency"):
        validate_artifact(bad)


def test_compare_dirs_and_run_baseline_gate(tmp_path):
    """End-to-end --baseline contract: identical dirs pass, a slowed
    scenario or a vanished artifact fails, a new artifact is ignored."""
    from benchmarks.run import main
    from repro.bench import compare_dirs

    base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
    spec = ScenarioSpec(name="gate.check", pattern="trivial", width=4,
                        height=8,
                        sweep=SweepControls(iterations_hi=64, n_points=3))
    res = run_scenario(spec, timer=SyntheticTimer())
    write_bench_json(res, str(base_dir))
    write_bench_json(res, str(cur_dir))
    results = compare_dirs(str(base_dir), str(cur_dir))
    assert len(results) == 1 and results[0].ok
    # new-in-current artifacts don't need a baseline
    res2 = run_scenario(
        spec := ScenarioSpec(name="gate.new", pattern="trivial", width=4,
                             height=8), timer=SyntheticTimer())
    write_bench_json(res2, str(cur_dir))
    assert all(r.ok for r in compare_dirs(str(base_dir), str(cur_dir)))
    # the CLI gate: same sweep vs itself passes...
    art = tmp_path / "cli"
    main(["--smoke", "--timer", "synthetic", "--only", "bench_peak",
          "--artifacts", str(art)])
    main(["--smoke", "--timer", "synthetic", "--only", "bench_peak",
          "--artifacts", str(tmp_path / "cli2"),
          "--baseline", str(art)])
    # ...and exits nonzero when a baseline artifact of a family this run
    # measured has no counterpart (a scenario vanished from the module)
    (tmp_path / "cli2" / os.listdir(art)[0]).rename(
        tmp_path / "cli2" / "BENCH_peak.renamed-away.json")
    with pytest.raises(SystemExit) as exc:
        main(["--smoke", "--timer", "synthetic", "--only", "bench_peak",
              "--artifacts", str(tmp_path / "cli3"),
              "--baseline", str(tmp_path / "cli2")])
    assert exc.value.code == 1
    # a partial run is NOT failed by baselines of families it never
    # remeasured (e.g. --only bench_peak vs the full committed
    # snapshot) — "missing" there means "not run", not "vanished"
    (tmp_path / "cli2" / "BENCH_peak.renamed-away.json").rename(
        tmp_path / "cli2" / "BENCH_otherfamily.cell.json")
    main(["--smoke", "--timer", "synthetic", "--only", "bench_peak",
          "--artifacts", str(tmp_path / "cli4"),
          "--baseline", str(tmp_path / "cli2")])


def test_compare_dirs_family_scoping(tmp_path):
    from repro.bench import compare_dirs, scenario_family

    assert scenario_family("BENCH_metg.xla-scan.nearest.json") == "metg"
    assert scenario_family("/x/BENCH_metg_deps.csp.radix3.json") == "metg_deps"
    base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
    for name in ("gate.a", "gate.b", "other.c"):
        res = run_scenario(
            ScenarioSpec(name=name, pattern="trivial", width=4, height=8),
            timer=SyntheticTimer())
        write_bench_json(res, str(base_dir))
        if name != "other.c":
            write_bench_json(res, str(cur_dir))
    # unscoped: other.c vanished -> regression
    assert not all(r.ok for r in compare_dirs(str(base_dir), str(cur_dir)))
    # scoped to the family that ran: other.* skipped, gate.* compared
    scoped = compare_dirs(str(base_dir), str(cur_dir), families={"gate"})
    assert len(scoped) == 2 and all(r.ok for r in scoped)
    # a gate.* scenario vanishing is still caught inside the scope
    os.remove(os.path.join(str(cur_dir), "BENCH_gate.b.json"))
    scoped = compare_dirs(str(base_dir), str(cur_dir), families={"gate"})
    assert any(not r.ok for r in scoped)


def test_committed_baselines_are_valid_artifacts():
    """The benchmarks/baselines/ snapshot the CI gate diffs against must
    itself read back clean (schema drift breaks here, not in CI)."""
    from repro.bench.compare import bench_json_names

    basedir = os.path.join(os.path.dirname(__file__), "..",
                           "benchmarks", "baselines")
    names = bench_json_names(basedir)
    assert len(names) >= 10, "baseline snapshot missing or too small"
    for f in names:
        doc = read_bench_json(os.path.join(basedir, f))
        assert doc["timer"] == "synthetic", (
            f"{f}: baselines must use the deterministic fake clock")


# -------------------------------------------------- core.metg compat shim
def test_core_metg_compat_shim_pins_reexports():
    """repro.core.metg is a pure re-export of repro.bench.metg: every
    advertised name must be the *same object* as the implementation's,
    and the historical import surface (repro.core.metg + repro.core)
    must keep resolving — so the next refactor cannot silently break the
    old path.  (Lives here, not in test_metg.py, so it runs even when
    hypothesis is absent.)"""
    import repro.bench.metg as impl
    import repro.core as core
    import repro.core.metg as shim

    expected = {"METGResult", "SweepPoint", "compute_metg",
                "efficiency_curve", "geometric_iterations", "run_sweep",
                "time_run"}
    assert set(shim.__all__) == expected
    for name in shim.__all__:
        assert getattr(shim, name) is getattr(impl, name), name
    # the package-level historical surface rides the same objects
    for name in ("METGResult", "SweepPoint", "compute_metg",
                 "geometric_iterations", "run_sweep"):
        assert getattr(core, name) is getattr(impl, name), name
    # and the shim stays callable end-to-end (not just importable)
    pts = [impl.SweepPoint(iterations=it, wall_time=64 * (1e-5 + it * 1e-8),
                           num_tasks=64, useful_work=64.0 * it * 2048,
                           granularity=1e-5 + it * 1e-8)
           for it in shim.geometric_iterations(1 << 16, 1, 2.0)]
    assert shim.compute_metg(pts, threshold=0.5).metg is not None


# ------------------------------------------------------- study families
def test_synthetic_timer_default_path_never_touches_the_backend():
    """The fake clock's study extensions (workers, seconds_per_byte) are
    opt-in: the default configuration must keep accepting backend names
    that do not exist (the closed-form model is backend-free)."""
    spec = ScenarioSpec(name="fake", backend="no-such-backend",
                        pattern="trivial", width=4, height=4)
    assert SyntheticTimer().measure(spec.backend, spec.graphs(8)) > 0


def test_synthetic_worker_model_matches_core_schedule():
    """workers > 1 charges the per-wavefront makespan of the backend's
    scheduling policy — exactly core.schedule's numbers."""
    from repro.core import make_graph
    from repro.core.schedule import wavefront_makespan

    g = make_graph(width=8, height=6, pattern="stencil", iterations=64,
                   imbalance=2.0)
    o, w = 20e-6, 2e-6
    timer = SyntheticTimer(overhead_per_task=o, seconds_per_iteration=w,
                           workers=4)
    for sched, policy in (("static", "static"), ("steal", "steal")):
        wall = timer.measure(f"host-dynamic[schedule={sched}]", [g])
        want = sum(
            wavefront_makespan(
                [o + g.task_iterations(t, i) * w for i in range(g.width)],
                4, policy)
            for t in range(g.height))
        assert wall == pytest.approx(want, rel=1e-12), sched
    # the backend's own pool size wins over the timer's — the charged
    # makespan must model the schedule the executor actually computed
    wall = timer.measure("host-dynamic[schedule=steal,workers=2]", [g])
    want = sum(
        wavefront_makespan(
            [o + g.task_iterations(t, i) * w for i in range(g.width)],
            2, "steal")
        for t in range(g.height))
    assert wall == pytest.approx(want, rel=1e-12)


def test_steal_mitigation_strictly_beats_static_at_imb2():
    """Acceptance: on the deterministic fake clock at imbalance=2.0 the
    work-stealing schedule retains strictly more of its balanced
    throughput than the static schedule."""
    from repro.bench.studies import (IMBALANCE_SECONDS_PER_ITERATION,
                                     STUDY_WORKERS, imbalance_spec,
                                     mitigation_curve, study_timer)

    timer = study_timer(SyntheticTimer(), workers=STUDY_WORKERS,
                        seconds_per_iteration=IMBALANCE_SECONDS_PER_ITERATION)
    results = {}
    for sched in ("static", "steal"):
        for imb in (0.0, 2.0):
            results[(imb, sched)] = run_scenario(
                imbalance_spec(schedule=sched, imbalance=imb), timer=timer)
    metric = {(p.x, p.variant): p.metric for p in mitigation_curve(results)}
    assert metric[(0.0, "static")] == metric[(0.0, "steal")] == 1.0
    assert metric[(2.0, "steal")] > metric[(2.0, "static")]


def test_comm_overlap_never_slower_on_fake_clock():
    """Acceptance: comm_overlap=True elapsed <= comm_overlap=False at
    every swept payload (max(compute, comm) vs compute + comm), for both
    SPMD backends."""
    from repro.bench.studies import (PAYLOAD_BYTES, SECONDS_PER_BYTE,
                                     elapsed_s, payload_spec, study_timer)

    timer = study_timer(SyntheticTimer(), seconds_per_byte=SECONDS_PER_BYTE)
    for backend in ("shardmap-csp", "shardmap-pipeline"):
        for ob in PAYLOAD_BYTES:
            off = run_scenario(
                payload_spec(backend, comm_overlap=False, output_bytes=ob),
                timer=timer)
            on = run_scenario(
                payload_spec(backend, comm_overlap=True, output_bytes=ob),
                timer=timer)
            assert elapsed_s(on) <= elapsed_s(off), (backend, ob)
            # both terms are positive here, so hiding is strictly real
            assert elapsed_s(on) < elapsed_s(off), (backend, ob)


def test_onesided_timer_model_closed_form():
    """The rendezvous surcharge is charged per dependency for the
    two-sided modes and skipped for comm="onesided", whose comm term is
    always overlappable (max(compute, comm)) even with comm_overlap off
    — the fake clock's model of put/signal with no matching step."""
    from repro.core import make_graph

    g = make_graph(width=8, height=16, pattern="stencil", iterations=64,
                   output_bytes=4096)
    ndeps = int(g.dependence_matrices().sum())
    t = SyntheticTimer(seconds_per_byte=4e-9, seconds_per_rendezvous=2e-6)
    compute = (g.num_tasks * t.overhead_per_task
               + g.total_iterations() * t.seconds_per_iteration)
    per_byte = g.output_bytes * t.seconds_per_byte
    blocking = t.measure("shardmap-csp[comm_overlap=False]", [g])
    assert blocking == pytest.approx(
        compute + ndeps * (per_byte + t.seconds_per_rendezvous), rel=1e-12)
    overlap = t.measure("shardmap-csp[comm_overlap=True]", [g])
    assert overlap == pytest.approx(
        max(compute, ndeps * (per_byte + t.seconds_per_rendezvous)),
        rel=1e-12)
    onesided = t.measure("shardmap-csp[comm=onesided]", [g])
    assert onesided == pytest.approx(max(compute, ndeps * per_byte),
                                     rel=1e-12)
    assert onesided <= overlap <= blocking
    # rendezvous alone (no per-byte cost) also reaches the backend hints
    t2 = SyntheticTimer(seconds_per_rendezvous=2e-6)
    assert t2.measure("shardmap-csp[comm_overlap=False]", [g]) == \
        pytest.approx(compute + ndeps * t2.seconds_per_rendezvous,
                      rel=1e-12)
    assert t2.measure("shardmap-csp[comm=onesided]", [g]) == \
        pytest.approx(compute, rel=1e-12)


def test_committed_study_baselines_show_the_tentpole_claims():
    """The acceptance numbers must be visible in the committed
    benchmarks/baselines/ snapshot itself: the stealing schedule's
    mitigation factor strictly beats static at imbalance=2.0, and the
    overlap variant's elapsed is <= blocking at every payload for
    shardmap-csp."""
    basedir = os.path.join(os.path.dirname(__file__), "..",
                           "benchmarks", "baselines")

    def point(name):
        doc = read_bench_json(os.path.join(basedir, f"BENCH_{name}.json"))
        assert len(doc["points"]) == 1, name  # fixed-granularity cell
        return doc["points"][0]

    def mitigation(sched, imb):
        obs = point(f"metg_imbalance.host-dynamic.{sched}.imb{imb}")
        bal = point(f"metg_imbalance.host-dynamic.{sched}.imb0.0")
        return obs["rate"] / bal["rate"]

    assert mitigation("steal", 2.0) > mitigation("static", 2.0)
    from repro.bench.studies import PAYLOAD_BYTES, overlap_efficiency
    for backend in ("shardmap-csp", "shardmap-pipeline"):
        smallest = min(PAYLOAD_BYTES)
        ideal = {v: point(f"metg_payload.{backend}.{v}.bytes{smallest}")
                 for v in ("blocking", "overlap", "onesided")}
        for ob in PAYLOAD_BYTES:
            blocking = point(f"metg_payload.{backend}.blocking.bytes{ob}")
            overlap = point(f"metg_payload.{backend}.overlap.bytes{ob}")
            onesided = point(f"metg_payload.{backend}.onesided.bytes{ob}")
            assert overlap["wall_time_s"] <= blocking["wall_time_s"], ob
            assert onesided["wall_time_s"] <= overlap["wall_time_s"], ob
            # the one-sided acceptance claim: its modeled overlap
            # efficiency >= the double-buffered variant's at EVERY point
            eff = {v: overlap_efficiency(ideal[v]["wall_time_s"],
                                         p["wall_time_s"])
                   for v, p in (("overlap", overlap),
                                ("onesided", onesided))}
            assert eff["onesided"] >= eff["overlap"], (backend, ob)


def test_study_curve_builders_validate_inputs():
    from repro.bench.studies import (DEGENERATE_METRIC, imbalance_spec,
                                     mitigation_curve, mitigation_factor,
                                     overlap_efficiency)

    # degenerate inputs clamp to the documented sentinel (never raise,
    # never emit inf/NaN — smoke runs can legitimately measure 0.0s)
    assert overlap_efficiency(0.0, 1.0) == DEGENERATE_METRIC
    assert overlap_efficiency(1.0, 0.0) == DEGENERATE_METRIC
    assert overlap_efficiency(float("inf"), 1.0) == DEGENERATE_METRIC
    assert overlap_efficiency(float("nan"), 1.0) == DEGENERATE_METRIC
    assert overlap_efficiency(1.0, 5e-324) == DEGENERATE_METRIC  # -> inf
    assert mitigation_factor(0.0, 1.0) == DEGENERATE_METRIC
    assert mitigation_factor(1.0, float("inf")) == DEGENERATE_METRIC
    assert mitigation_factor(-1.0, 1.0) == DEGENERATE_METRIC
    import math
    assert math.isfinite(DEGENERATE_METRIC)
    # well-formed inputs still compute the plain ratio
    assert overlap_efficiency(1.0, 2.0) == 0.5
    assert mitigation_factor(2.0, 1.0) == 0.5
    # mitigation needs the balanced baseline cell
    res = run_scenario(imbalance_spec(schedule="steal", imbalance=1.5),
                       timer=SyntheticTimer())
    with pytest.raises(ValueError, match="balanced"):
        mitigation_curve({(1.5, "steal"): res})


def test_task_iterations_conservation_within_rounding_bound():
    """Imbalance scaling conserves the graph's total iterations within
    the documented rounding bound (num_tasks / 2 of the analytic sum),
    and every task stays in [1, iterations]."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.core import make_graph
    from repro.core.graph import _imbalance_u

    @settings(max_examples=40, deadline=None)
    @given(width=st.integers(1, 16), height=st.integers(1, 10),
           iters=st.integers(1, 4096),
           imbalance=st.sampled_from([0.0, 0.5, 1.5, 3.0]),
           seed=st.integers(0, 3))
    def check(width, height, iters, imbalance, seed):
        g = make_graph(width=width, height=height, pattern="trivial",
                       iterations=iters, imbalance=imbalance, seed=seed)
        per = [g.task_iterations(t, i)
               for t in range(height) for i in range(width)]
        assert all(1 <= p <= iters for p in per)
        assert g.total_iterations() == sum(per)  # the single definition
        analytic = sum(
            max(1.0, iters * (1.0 - imbalance * _imbalance_u(t, i, seed)))
            for t in range(height) for i in range(width))
        assert abs(g.total_iterations() - analytic) <= 0.5 * g.num_tasks

    check()


# ------------------------------------- study-family compare negative paths
def test_compare_refuses_mixed_family_study_artifacts():
    """A metg_payload artifact diffed against a metg_imbalance artifact is
    an identity mismatch, not a perf signal — the differ must refuse
    before comparing any numbers."""
    from repro.bench import compare_artifacts
    from repro.bench.studies import imbalance_spec, payload_spec

    pay = bench_artifact(run_scenario(payload_spec(output_bytes=16),
                                      timer=SyntheticTimer()))
    imb = bench_artifact(run_scenario(imbalance_spec(imbalance=0.0),
                                      timer=SyntheticTimer()))
    res = compare_artifacts(pay, imb, rel_threshold=0.25)
    assert not res.ok
    assert any("scenario.name changed" in r for r in res.regressions)
    assert res.metg_baseline is None and not res.points  # refused early


def test_compare_dirs_vanished_study_scenario_scoped_within_family(tmp_path):
    """Family scoping over the new families: a vanished metg_payload cell
    regresses inside families={"metg_payload"}, while the untouched
    metg_imbalance baselines are skipped (a partial --only run)."""
    from repro.bench import compare_dirs
    from repro.bench.compare import scenario_family
    from repro.bench.studies import imbalance_spec, payload_spec

    assert scenario_family(
        "BENCH_metg_payload.shardmap-csp.overlap.bytes16.json") == \
        "metg_payload"
    assert scenario_family(
        "BENCH_metg_imbalance.host-dynamic.steal.imb2.0.json") == \
        "metg_imbalance"
    base, cur = tmp_path / "base", tmp_path / "cur"
    for ob in (16, 4096):
        res = run_scenario(payload_spec(output_bytes=ob),
                           timer=SyntheticTimer())
        write_bench_json(res, str(base))
        if ob == 16:
            write_bench_json(res, str(cur))  # bytes4096 vanishes
    res = run_scenario(imbalance_spec(imbalance=0.0), timer=SyntheticTimer())
    write_bench_json(res, str(base))  # other family, never remeasured
    scoped = compare_dirs(str(base), str(cur), families={"metg_payload"})
    assert len(scoped) == 2  # imbalance baseline skipped entirely
    assert any(not r.ok and "missing" in "".join(r.regressions)
               for r in scoped)
    # with the vanished cell restored, the scoped diff is clean
    res = run_scenario(payload_spec(output_bytes=4096),
                       timer=SyntheticTimer())
    write_bench_json(res, str(cur))
    assert all(r.ok for r in compare_dirs(str(base), str(cur),
                                          families={"metg_payload"}))


def test_study_regression_fixture_trips_the_gate(tmp_path, capsys):
    """End-to-end: a synthetic-timer baseline whose study numbers are
    made 2x faster than reality must fail `--baseline` with a nonzero
    exit (the committed-snapshot contract for the new families)."""
    from benchmarks.run import main

    good = tmp_path / "good"
    main(["--smoke", "--timer", "synthetic", "--only",
          "bench_metg_imbalance", "--artifacts", str(good)])
    capsys.readouterr()
    tampered = tmp_path / "tampered"
    os.makedirs(tampered)
    for fname in os.listdir(good):
        doc = read_bench_json(os.path.join(good, fname))
        for p in doc["points"]:
            p["wall_time_s"] *= 0.5  # baseline claims twice the speed
        if doc["metg_s"] is not None:
            doc["metg_s"] *= 0.5
        with open(os.path.join(tampered, fname), "w") as f:
            json.dump(doc, f)
    with pytest.raises(SystemExit) as exc:
        main(["--smoke", "--timer", "synthetic", "--only",
              "bench_metg_imbalance", "--artifacts", str(tmp_path / "cur"),
              "--baseline", str(tampered)])
    assert exc.value.code == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out


# --------------------------------------------------- moe_dispatch scenario
def test_moe_dispatch_sp_cuts_a2a_volume_by_model_axis():
    """The tentpole's measurable claim, asserted (not just printed): the
    SP-aware EP scenario's per-plane a2a bytes are exactly 1/|model| of
    the replicated scenario's, for more than one mesh shape.  (The same
    numbers are re-verified against compiled HLO on an 8-rank mesh in
    test_distributed.py.)"""
    from repro.bench import MoEDispatchSpec, analytic_a2a_bytes

    for data, model in ((4, 2), (2, 4), (8, 2)):
        rep = analytic_a2a_bytes(MoEDispatchSpec(
            data=data, model=model, ep_mode="replicated"))
        sp = analytic_a2a_bytes(MoEDispatchSpec(
            data=data, model=model, ep_mode="sp"))
        assert rep["a2a_bytes"] == sp["a2a_bytes"] * model, (data, model)
        assert rep["dispatch_planes"] == model
        assert sp["dispatch_planes"] == 1
        # total over planes: sp moves the replicated single-plane volume
        assert sp["a2a_bytes_all_planes"] == rep["a2a_bytes"]
        assert sp["sp_effective"] == 1.0 and rep["sp_effective"] == 0.0


def test_moe_dispatch_analytic_models_divisibility_fallback():
    """An sp spec whose sequence does not divide `model` runs replicated
    in the kernel (models.moe divisibility fallback) — the analytic model
    must report the replicated volume, not a phantom SP reduction."""
    from repro.bench import MoEDispatchSpec, analytic_a2a_bytes

    sp = analytic_a2a_bytes(MoEDispatchSpec(seq=30, model=4, data=2,
                                            ep_mode="sp"))
    rep = analytic_a2a_bytes(MoEDispatchSpec(seq=30, model=4, data=2,
                                             ep_mode="replicated"))
    assert sp["sp_effective"] == 0.0
    assert sp["a2a_bytes"] == rep["a2a_bytes"]
    assert sp["dispatch_planes"] == rep["dispatch_planes"] == 4


def test_moe_dispatch_report_roofline_terms():
    from repro.bench import MoEDispatchSpec, moe_dispatch_report
    from repro.launch.roofline import LINK_BW

    rep = moe_dispatch_report(MoEDispatchSpec())
    assert rep["a2a_roofline_s"] == pytest.approx(rep["a2a_bytes"] / LINK_BW)
    assert "hlo_a2a_bytes" not in rep  # compiled path not requested


def test_bench_moe_dispatch_module_reports_reduction():
    from benchmarks.bench_moe_dispatch import run as run_moe
    from benchmarks.common import BenchContext

    rows = run_moe(BenchContext(smoke=True))
    byname = {r.name: r for r in rows}
    red = byname["moe_dispatch.d4m2.reduction"]
    assert "a2a_ratio=2.00" in red.derived


# ------------------------------------------------- benchmarks CLI contract
def test_benchmarks_smoke_emits_valid_artifacts(tmp_path, capsys):
    """`python -m benchmarks.run --smoke` writes >= 1 schema-valid
    BENCH_*.json (the acceptance contract for the CI artifact upload)."""
    from benchmarks.run import main

    main(["--smoke", "--only", "bench_peak",
          "--artifacts", str(tmp_path)])
    out = capsys.readouterr().out
    assert "name,us_per_call,derived" in out
    files = sorted(p for p in os.listdir(tmp_path)
                   if p.startswith("BENCH_") and p.endswith(".json"))
    assert files, "no BENCH_*.json emitted"
    for f in files:
        doc = read_bench_json(os.path.join(tmp_path, f))
        assert doc["scenario"]["sweep"]["smoke"] is True


def test_tables_flag_round_trips_metg_summary(tmp_path, capsys):
    """`--tables` turns the run's own artifacts into the paper-style METG
    summary: sweep -> BENCH_*.json -> append_tables -> markdown table
    with one row per backend (pallas-fused included) under the marker."""
    from benchmarks.run import main

    md = tmp_path / "EXP.md"
    main(["--smoke", "--timer", "synthetic",
          "--only", "bench_metg_patterns",
          "--artifacts", str(tmp_path), "--tables",
          "--tables-file", str(md)])
    assert f"tables,0,{md}" in capsys.readouterr().out
    text = md.read_text()
    assert "## §Tables (generated)" in text
    assert "METG(50%)" in text
    assert "| pallas-fused |" in text and "| xla-scan |" in text
    # regenerating replaces the generated section instead of stacking it
    import append_tables

    append_tables.append_metg_tables(str(tmp_path), str(md))
    assert md.read_text().count("## §Tables (generated)") == 1


def test_append_metg_tables_over_committed_baselines(tmp_path):
    """The committed benchmarks/baselines directory renders directly —
    fused rows carry numeric µs cells strictly below xla-scan's."""
    import append_tables

    baselines = os.path.join(os.path.dirname(__file__), "..",
                             "benchmarks", "baselines")
    md = tmp_path / "EXP.md"
    append_tables.append_metg_tables(baselines, str(md))
    table = md.read_text()
    fused = [l for l in table.splitlines()
             if l.startswith("| pallas-fused |")]
    assert fused, "no pallas-fused rows rendered"
    with pytest.raises(ValueError, match="no valid BENCH"):
        append_tables.append_metg_tables(str(tmp_path / "empty"), str(md))


def test_bench_context_threads_smoke_and_artifacts(tmp_path):
    from benchmarks.common import BenchContext, metg_for

    ctx = BenchContext(smoke=True, artifacts_dir=str(tmp_path),
                       timer=SyntheticTimer())
    res = metg_for(ctx, "xla-scan", "stencil", name="ctx.check",
                   iterations_hi=4096, n_points=6)
    assert res.peak_rate > 0
    assert len(res.points) <= SMOKE_N_POINTS  # smoke reached the sweep
    assert ctx.written and ctx.written[0].endswith("BENCH_ctx.check.json")
    read_bench_json(ctx.written[0])


def test_bench_context_rejects_slug_collision(tmp_path):
    """Distinct scenario names that slugify identically must not silently
    clobber each other's artifacts within one run — and the guard fires
    *before* the earlier artifact is overwritten."""
    from benchmarks.common import BenchContext, metg_for

    ctx = BenchContext(smoke=True, artifacts_dir=str(tmp_path),
                       timer=SyntheticTimer())
    metg_for(ctx, "xla-scan", "trivial", name="clash x1")
    with pytest.raises(ValueError, match="distinct slugs"):
        metg_for(ctx, "xla-scan", "trivial", name="clash-x1")
    # the first scenario's artifact survived intact
    assert read_bench_json(ctx.written[0])["scenario"]["name"] == "clash x1"
