"""Pipeline parallelism: schedule = sweep graph; execution = reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.dist import pipeline as PP
from repro.models import model as M
from repro.models.layers import split_leaves


def test_schedule_is_sweep_graph():
    g = PP.pp_schedule(num_stages=4, num_micro=6)
    assert g.pattern == "sweep"
    assert g.width == 4 and g.height == 9  # M + S - 1 ticks
    # stage s depends on itself and its left neighbour — the wavefront
    assert g.deps(3, 2) == [1, 2]
    assert g.deps(1, 0) == [0]


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("yi-6b"))
    cfg = dataclasses.replace(cfg, num_layers=4)
    params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                              cfg.vocab_size)
    return cfg, params, toks


@pytest.mark.parametrize("stages,micro", [(2, 4), (4, 8), (2, 2)])
def test_pp_forward_matches_reference(setup, stages, micro):
    cfg, params, toks = setup
    ref_logits, _, _ = M.forward(params, cfg, tokens=toks)
    pp_params = PP.stack_params_by_stage(params, num_stages=stages)
    pp_logits = PP.pp_forward(pp_params, cfg, toks, stages, micro)
    np.testing.assert_allclose(np.asarray(pp_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_pp_gradients_flow(setup):
    cfg, params, toks = setup
    pp_params = PP.stack_params_by_stage(params, num_stages=2)
    batch = {"tokens": toks, "labels": toks}
    g = jax.grad(lambda p: PP.pp_loss_fn(p, cfg, batch, 2, 4)[0])(pp_params)
    total = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
    # every stage's blocks received gradient
    gb = g["blocks_scanned"]
    leaf = jax.tree.leaves(gb)[0]
    assert leaf.shape[0] == 2
    assert all(float(jnp.abs(leaf[s]).sum()) > 0 for s in range(2))
