"""The comm-planning layer: reach analysis, mode selection, ragged padding.

Planning is pure host-side numpy — no devices needed; execution of the
plans is covered by the backend conformance tests (1-device here,
8-device in test_distributed.py).
"""
import numpy as np
import pytest

from repro.core import make_graph, pattern_names
from repro.dist import collectives as CC
from repro.launch.mesh import production_mesh_spec


def brute_force_reach(g):
    """The old per-timestep Python loop the vectorized analysis replaced."""
    reach = 0
    for t in range(1, g.height):
        for i, j in np.argwhere(g.dependence_matrix(t)):
            reach = max(reach, abs(int(j) - int(i)))
    return reach


@pytest.mark.parametrize("pattern", pattern_names())
def test_reach_matches_brute_force(pattern):
    kw = {"radix": 5} if pattern in ("nearest", "spread") else {}
    g = make_graph(width=8, height=10, pattern=pattern, iterations=1, **kw)
    assert CC.dependency_reach(g) == brute_force_reach(g)


def test_directional_reach():
    assert CC.directional_reach(make_graph(pattern="sweep")) == (1, 0)
    assert CC.directional_reach(make_graph(pattern="stencil")) == (1, 1)
    assert CC.directional_reach(make_graph(pattern="trivial")) == (0, 0)
    assert CC.directional_reach(make_graph(pattern="no_comm")) == (0, 0)


def test_dependence_matrices_cached():
    g = make_graph(width=6, height=8, pattern="fft")
    assert g.dependence_matrices() is g.dependence_matrices()
    # cached stack is protected against accidental mutation
    with pytest.raises(ValueError):
        g.dependence_matrices()[0, 0, 0] = True


def test_mode_selection():
    sweep = make_graph(width=8, height=6, pattern="sweep")
    stencil = make_graph(width=8, height=6, pattern="stencil")
    fft = make_graph(width=8, height=6, pattern="fft")

    assert CC.plan_comm(sweep, 4, "stage", prefer_ring=True).mode == "ring"
    assert CC.plan_comm(sweep, 4, "cols").mode == "halo"  # CSP default
    assert CC.plan_comm(stencil, 4, "stage", prefer_ring=True).mode == "halo"
    # fft reach 4 > 2 local columns -> gather
    assert CC.plan_comm(fft, 4, "cols").mode == "allgather"
    assert CC.plan_comm(fft, 1, "cols").mode == "halo"  # fits on one rank


def test_forced_modes_validate():
    stencil = make_graph(width=8, height=6, pattern="stencil")
    fft = make_graph(width=8, height=6, pattern="fft")
    with pytest.raises(ValueError, match="left-only"):
        CC.plan_comm(stencil, 4, "cols", comm="ring")
    with pytest.raises(ValueError, match="cannot cover reach"):
        CC.plan_comm(fft, 4, "cols", comm="halo")
    with pytest.raises(ValueError, match="unknown comm mode"):
        CC.plan_comm(stencil, 4, "cols", comm="bogus")
    assert CC.plan_comm(fft, 4, "cols", comm="allgather").mode == "allgather"


def test_ragged_padding_dead_columns():
    g = make_graph(width=10, height=8, pattern="stencil", iterations=4)
    plan = CC.plan_comm(g, 4, "cols")
    assert plan.ragged
    assert (plan.padded_width, plan.local, plan.halo) == (12, 3, 1)
    # dead columns: no work, no dependence rows
    assert (plan.iters[:, 10:] == 0).all()
    assert (plan.local_mats[:, 10:] == 0).all()
    assert (plan.iters[:, :10] > 0).all()
    assert plan.trim(np.arange(12)).shape == (10,)


def test_width_smaller_than_ranks():
    g = make_graph(width=4, height=6, pattern="sweep")
    plan = CC.plan_comm(g, 8, "stage", prefer_ring=True)
    assert (plan.padded_width, plan.local, plan.mode) == (8, 1, "ring")


@pytest.mark.parametrize("pattern,kw", [
    ("stencil", {}), ("sweep", {}), ("nearest", {"radix": 5}),
])
def test_local_matrices_reindex_correctly(pattern, kw):
    """Every global dep (t, i) <- (t-1, j) lands at its context offset."""
    g = make_graph(width=12, height=6, pattern=pattern, iterations=1, **kw)
    plan = CC.plan_comm(g, 4, "cols")
    assert plan.mode in ("halo", "ring")
    lhalo = plan.halo
    for t in range(g.height):
        want = np.zeros((plan.padded_width, plan.context_width), np.uint8)
        for i in range(g.width):
            for j in g.deps(t, i):
                want[i, j - ((i // plan.local) * plan.local - lhalo)] = 1
        np.testing.assert_array_equal(plan.local_mats[t], want)


def test_time_varying_pattern_analyzed_fully():
    """fft's reach grows with t; the invariance short-circuit must not
    clip the analysis to the first timestep."""
    g = make_graph(width=16, height=5, pattern="fft")
    assert not g.is_time_invariant()
    assert CC.dependency_reach(g) == 8  # stride at the deepest level


# ------------------------------------------------------------- a2a mode
def _brute_force_pair_counts(g, ndev, local):
    """[src, dst] distinct remote columns needed, straight from g.deps."""
    need = set()
    for t in range(1, g.height):
        for i in range(g.width):
            for j in g.deps(t, i):
                if j // local != i // local:
                    need.add((j // local, i // local, j))
    counts = np.zeros((ndev, ndev), np.int64)
    for s, d, _ in need:
        counts[s, d] += 1
    return counts


@pytest.mark.parametrize("pattern,kw", [
    ("stencil", {}), ("sweep", {}), ("fft", {}),
    ("spread", {"radix": 3}), ("random", {}),
])
def test_a2a_plan_counts_match_deps(pattern, kw):
    g = make_graph(width=12, height=6, pattern=pattern, iterations=1, **kw)
    plan = CC.plan_comm(g, 4, "cols", comm="a2a")
    assert plan.mode == "a2a"
    want = _brute_force_pair_counts(g, 4, plan.local)
    np.testing.assert_array_equal(plan.send_counts, want)
    # permutation: every row sent is received exactly once
    np.testing.assert_array_equal(plan.recv_counts, plan.send_counts.T)
    assert plan.send_counts.sum() == plan.recv_counts.sum()
    assert (np.diag(plan.send_counts) == 0).all()  # local rows never move
    assert plan.a2a_cap == plan.send_counts.max()


def test_a2a_local_matrices_reindex_correctly():
    """Every dep lands at its [recv buffers | local block] context offset:
    remote j from rank s at slot k -> s*cap + k (slots in sorted column
    order per pair), local j -> ndev*cap + (j - r*local)."""
    g = make_graph(width=12, height=6, pattern="stencil", iterations=1)
    plan = CC.plan_comm(g, 4, "cols", comm="a2a")
    ndev, cap, local = plan.ndev, plan.a2a_cap, plan.local
    for t in range(g.height):
        want = np.zeros((plan.padded_width, plan.context_width), np.uint8)
        for i in range(g.width):
            r = i // local
            for j in g.deps(t, i):
                s = j // local
                if s == r:
                    want[i, ndev * cap + (j - r * local)] = 1
                else:
                    cols = sorted({jj for tt in range(1, g.height)
                                   for ii in range(g.width)
                                   if ii // local == r
                                   for jj in g.deps(tt, ii)
                                   if jj // local == s})
                    want[i, s * cap + cols.index(j)] = 1
        np.testing.assert_array_equal(plan.local_mats[t], want)


def test_a2a_mode_must_be_requested_and_handles_degenerates():
    g = make_graph(width=8, height=6, pattern="fft")
    assert CC.plan_comm(g, 4, "cols").mode == "allgather"  # auto never a2a
    plan = CC.plan_comm(g, 4, "cols", comm="a2a")
    assert plan.mode == "a2a" and plan.halo == 0
    # single rank: nothing remote, empty buffers, context == local block
    p1 = CC.plan_comm(g, 1, "cols", comm="a2a")
    assert p1.a2a_cap == 0 and p1.send_counts.sum() == 0
    assert p1.context_width == p1.local
    # no-comm graph: counts all zero on any rank count
    triv = CC.plan_comm(make_graph(width=8, height=6, pattern="trivial"),
                        4, "cols", comm="a2a")
    assert triv.send_counts.sum() == 0 and triv.a2a_cap == 0
    # ragged: dead padding columns neither send nor receive
    gr = make_graph(width=10, height=8, pattern="stencil", iterations=4)
    pr = CC.plan_comm(gr, 4, "cols", comm="a2a")
    assert pr.ragged and (pr.local_mats[:, 10:] == 0).all()


# -------------------------------------------------- one-sided mode layout
def _check_onesided_exactly_once(g, ndev):
    """The put/signal layout contract, brute-forced from g.deps:

    * ring covering — every live (src, dst) pair is served by exactly one
      ring offset, dead pairs by none;
    * slot injectivity — each pair's live put slots carry distinct local
      rows, exactly ``send_counts`` of them;
    * delivery — every dependency of every task resolves through exactly
      one context slot (recv slot for remote producers, local block
      otherwise), and no slot delivers a column the task doesn't need.
    """
    plan = CC.plan_comm(g, ndev, "cols", comm="onesided")
    assert plan.mode == "onesided"
    cap, local = plan.a2a_cap, plan.local
    served = {}
    for off, idx_tab, live in plan._onesided_offsets:
        for s in range(ndev):
            d = (s + off) % ndev
            if plan.send_counts[s, d] > 0:
                assert live[s] == 1.0, (s, d, off)
                assert (s, d) not in served  # one offset per pair
                served[(s, d)] = idx_tab[s]
            else:
                assert live[s] == 0.0, (s, d, off)
    assert set(served) == {(s, d)
                           for s in range(ndev) for d in range(ndev)
                           if plan.send_counts[s, d] > 0}
    for (s, d), rows in served.items():
        n = int(plan.send_counts[s, d])
        assert len(set(rows[:n].tolist())) == n  # injective live prefix
        np.testing.assert_array_equal(rows, plan.a2a_send_idx[s, d])
        assert ((rows >= 0) & (rows < local)).all()
    # dead padded columns neither produce nor consume
    assert (plan.local_mats[:, g.width:] == 0).all()
    for t in range(g.height):
        for i in range(g.width):
            d = i // local
            got = []
            for c in np.nonzero(plan.local_mats[t, i])[0]:
                if c >= ndev * cap:  # the local block
                    got.append(d * local + (c - ndev * cap))
                else:  # recv slot: decode via the put schedule
                    s, k = c // cap, c % cap
                    assert k < plan.send_counts[s, d], (t, i, s, k)
                    got.append(s * local + int(plan.a2a_send_idx[s, d, k]))
            assert sorted(got) == sorted(g.deps(t, i)), (t, i)


@pytest.mark.parametrize("ndev", [1, 2, 4, 8])
def test_onesided_layout_delivers_each_dep_exactly_once(ndev):
    """Exhaustive deterministic sweep of the property: widths 1-16 over
    1/2/4/8 ranks for stencil, plus the densest patterns at mixed
    widths."""
    for width in range(1, 17):
        g = make_graph(width=width, height=6, pattern="stencil",
                       iterations=1)
        _check_onesided_exactly_once(g, ndev)
    for pattern, kw in [("fft", {}), ("spread", {"radix": 3}),
                        ("random", {}), ("sweep", {})]:
        for width in (3, 10, 16):
            g = make_graph(width=width, height=6, pattern=pattern,
                           iterations=1, **kw)
            _check_onesided_exactly_once(g, ndev)


def test_onesided_layout_property_randomized():
    """The same contract under hypothesis-driven (width, ndev, pattern,
    seed) sampling — catches layout corners the grid above misses."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(width=st.integers(1, 16), ndev=st.sampled_from([1, 2, 4, 8]),
           pattern=st.sampled_from(["stencil", "sweep", "fft", "random"]),
           seed=st.integers(0, 3))
    def check(width, ndev, pattern, seed):
        g = make_graph(width=width, height=5, pattern=pattern,
                       iterations=1, seed=seed)
        _check_onesided_exactly_once(g, ndev)

    check()


def test_onesided_plan_shares_a2a_accounting():
    """One-sided reuses the a2a slot accounting: same counts, same cap,
    same sorted-column send schedule — only the transport differs."""
    g = make_graph(width=12, height=6, pattern="stencil", iterations=1)
    a2a = CC.plan_comm(g, 4, "cols", comm="a2a")
    one = CC.plan_comm(g, 4, "cols", comm="onesided")
    np.testing.assert_array_equal(one.send_counts, a2a.send_counts)
    np.testing.assert_array_equal(one.a2a_send_idx, a2a.a2a_send_idx)
    np.testing.assert_array_equal(one.local_mats, a2a.local_mats)
    assert one.a2a_cap == a2a.a2a_cap


def test_a2a_forced_execution_matches_oracle():
    """The a2a exchange path through the CSP backend (1 device here; the
    8-rank version lives in test_distributed.py)."""
    from repro.backends import get_backend
    from repro.core import check_outputs

    for pat, kw in [("stencil", {}), ("spread", {"radix": 3}), ("fft", {})]:
        g = make_graph(width=6, height=8, pattern=pat, iterations=3, **kw)
        be = get_backend("shardmap-csp", comm="a2a")
        assert be.plan(g).mode == "a2a"
        check_outputs(g, be.run([g])[0])


# -------------------------------------------------- token dispatch plan
def test_dispatch_capacity_math():
    assert CC.dispatch_capacity(512, 4, 8.0) == 1024
    assert CC.dispatch_capacity(1, 8, 1.0) == 8      # floor
    assert CC.dispatch_capacity(100, 4, 1.0) == 32   # ceil to multiple of 8
    # SP-aware EP: sends cut by |model| cuts capacity proportionally
    assert CC.dispatch_capacity(512 // 2, 4, 8.0) == 512


def test_token_a2a_roundtrip_single_rank():
    """dispatch -> combine is the identity for kept rows (ndev=1 runs the
    full slotting/capacity path without a mesh)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    plan = CC.TokenA2APlan(axis="d", ndev=1, cap=8)
    rows = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    dest = jnp.zeros(6, jnp.int32)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))

    def f(r):
        slot, keep = plan.route(dest)
        recv = plan.dispatch(dest, slot, r)
        assert recv.shape == (8, 2)
        back = plan.combine(recv, dest, slot)
        return back * keep[:, None], keep

    got, keep = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False)(rows)
    assert np.asarray(keep).all()  # cap 8 >= 6 rows: nothing dropped
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows))


def test_token_a2a_capacity_drop_is_deterministic():
    """Rows beyond cap are dropped in send order (paper-style capacity)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    plan = CC.TokenA2APlan(axis="d", ndev=1, cap=8)
    rows = jnp.arange(20, dtype=jnp.float32)[:, None]
    dest = jnp.zeros(20, jnp.int32)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))

    def f(r):
        slot, keep = plan.route(dest)
        return keep

    keep = np.asarray(shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                                check_vma=False)(rows))
    assert keep[:8].all() and not keep[8:].any()


# ------------------------------------------------- production mesh spec
def test_production_mesh_spec_grows_stage_axis():
    assert production_mesh_spec() == ((16, 16), ("data", "model"))
    assert production_mesh_spec(multi_pod=True) == \
        ((2, 16, 16), ("pod", "data", "model"))
    shape, axes = production_mesh_spec(multi_pod=True, pipeline_stages=4)
    assert shape == (2, 4, 16, 4)
    assert axes == ("pod", "data", "model", "stage")
    assert np.prod(shape) == 512  # chip count preserved
    with pytest.raises(ValueError, match="not divisible"):
        production_mesh_spec(pipeline_stages=3)
