"""The comm-planning layer: reach analysis, mode selection, ragged padding.

Planning is pure host-side numpy — no devices needed; execution of the
plans is covered by the backend conformance tests (1-device here,
8-device in test_distributed.py).
"""
import numpy as np
import pytest

from repro.core import make_graph, pattern_names
from repro.dist import collectives as CC
from repro.launch.mesh import production_mesh_spec


def brute_force_reach(g):
    """The old per-timestep Python loop the vectorized analysis replaced."""
    reach = 0
    for t in range(1, g.height):
        for i, j in np.argwhere(g.dependence_matrix(t)):
            reach = max(reach, abs(int(j) - int(i)))
    return reach


@pytest.mark.parametrize("pattern", pattern_names())
def test_reach_matches_brute_force(pattern):
    kw = {"radix": 5} if pattern in ("nearest", "spread") else {}
    g = make_graph(width=8, height=10, pattern=pattern, iterations=1, **kw)
    assert CC.dependency_reach(g) == brute_force_reach(g)


def test_directional_reach():
    assert CC.directional_reach(make_graph(pattern="sweep")) == (1, 0)
    assert CC.directional_reach(make_graph(pattern="stencil")) == (1, 1)
    assert CC.directional_reach(make_graph(pattern="trivial")) == (0, 0)
    assert CC.directional_reach(make_graph(pattern="no_comm")) == (0, 0)


def test_dependence_matrices_cached():
    g = make_graph(width=6, height=8, pattern="fft")
    assert g.dependence_matrices() is g.dependence_matrices()
    # cached stack is protected against accidental mutation
    with pytest.raises(ValueError):
        g.dependence_matrices()[0, 0, 0] = True


def test_mode_selection():
    sweep = make_graph(width=8, height=6, pattern="sweep")
    stencil = make_graph(width=8, height=6, pattern="stencil")
    fft = make_graph(width=8, height=6, pattern="fft")

    assert CC.plan_comm(sweep, 4, "stage", prefer_ring=True).mode == "ring"
    assert CC.plan_comm(sweep, 4, "cols").mode == "halo"  # CSP default
    assert CC.plan_comm(stencil, 4, "stage", prefer_ring=True).mode == "halo"
    # fft reach 4 > 2 local columns -> gather
    assert CC.plan_comm(fft, 4, "cols").mode == "allgather"
    assert CC.plan_comm(fft, 1, "cols").mode == "halo"  # fits on one rank


def test_forced_modes_validate():
    stencil = make_graph(width=8, height=6, pattern="stencil")
    fft = make_graph(width=8, height=6, pattern="fft")
    with pytest.raises(ValueError, match="left-only"):
        CC.plan_comm(stencil, 4, "cols", comm="ring")
    with pytest.raises(ValueError, match="cannot cover reach"):
        CC.plan_comm(fft, 4, "cols", comm="halo")
    with pytest.raises(ValueError, match="unknown comm mode"):
        CC.plan_comm(stencil, 4, "cols", comm="bogus")
    assert CC.plan_comm(fft, 4, "cols", comm="allgather").mode == "allgather"


def test_ragged_padding_dead_columns():
    g = make_graph(width=10, height=8, pattern="stencil", iterations=4)
    plan = CC.plan_comm(g, 4, "cols")
    assert plan.ragged
    assert (plan.padded_width, plan.local, plan.halo) == (12, 3, 1)
    # dead columns: no work, no dependence rows
    assert (plan.iters[:, 10:] == 0).all()
    assert (plan.local_mats[:, 10:] == 0).all()
    assert (plan.iters[:, :10] > 0).all()
    assert plan.trim(np.arange(12)).shape == (10,)


def test_width_smaller_than_ranks():
    g = make_graph(width=4, height=6, pattern="sweep")
    plan = CC.plan_comm(g, 8, "stage", prefer_ring=True)
    assert (plan.padded_width, plan.local, plan.mode) == (8, 1, "ring")


@pytest.mark.parametrize("pattern,kw", [
    ("stencil", {}), ("sweep", {}), ("nearest", {"radix": 5}),
])
def test_local_matrices_reindex_correctly(pattern, kw):
    """Every global dep (t, i) <- (t-1, j) lands at its context offset."""
    g = make_graph(width=12, height=6, pattern=pattern, iterations=1, **kw)
    plan = CC.plan_comm(g, 4, "cols")
    assert plan.mode in ("halo", "ring")
    lhalo = plan.halo
    for t in range(g.height):
        want = np.zeros((plan.padded_width, plan.context_width), np.uint8)
        for i in range(g.width):
            for j in g.deps(t, i):
                want[i, j - ((i // plan.local) * plan.local - lhalo)] = 1
        np.testing.assert_array_equal(plan.local_mats[t], want)


def test_time_varying_pattern_analyzed_fully():
    """fft's reach grows with t; the invariance short-circuit must not
    clip the analysis to the first timestep."""
    g = make_graph(width=16, height=5, pattern="fft")
    assert not g.is_time_invariant()
    assert CC.dependency_reach(g) == 8  # stride at the deepest level


# ------------------------------------------------- production mesh spec
def test_production_mesh_spec_grows_stage_axis():
    assert production_mesh_spec() == ((16, 16), ("data", "model"))
    assert production_mesh_spec(multi_pod=True) == \
        ((2, 16, 16), ("pod", "data", "model"))
    shape, axes = production_mesh_spec(multi_pod=True, pipeline_stages=4)
    assert shape == (2, 4, 16, 4)
    assert axes == ("pod", "data", "model", "stage")
    assert np.prod(shape) == 512  # chip count preserved
    with pytest.raises(ValueError, match="not divisible"):
        production_mesh_spec(pipeline_stages=3)
