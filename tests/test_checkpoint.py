"""Checkpoint roundtrip, atomic commit, latest-step discovery."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


@pytest.fixture
def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                   "step": jnp.int32(7)},
    }


def test_roundtrip_identity(tree, tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 5, tree, async_write=False)
    assert ckpt.latest_step(d) == 5
    restored = ckpt.restore(d, 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_picks_max(tree, tmp_path):
    d = str(tmp_path)
    for s in (10, 30, 20):
        ckpt.save(d, s, tree, async_write=False)
    assert ckpt.latest_step(d) == 30


def test_uncommitted_checkpoint_ignored(tree, tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, tree, async_write=False)
    # a torn write: directory without manifest
    os.makedirs(os.path.join(d, "step_99"))
    assert ckpt.latest_step(d) == 1


def test_async_write_joins(tree, tmp_path):
    d = str(tmp_path)
    t = ckpt.save(d, 2, tree, async_write=True)
    t.join()
    assert ckpt.latest_step(d) == 2


def test_structure_mismatch_raises(tree, tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 3, tree, async_write=False)
    other = {"different": jnp.zeros(3)}
    with pytest.raises(AssertionError):
        ckpt.restore(d, 3, other)
