"""Divisibility-fallback sharding rules (duck-typed mesh, no devices)."""
from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import ShardingRules, make_rules


def fake_mesh(**shape):
    return SimpleNamespace(shape=shape)


def rules_for(**shape):
    return make_rules(fake_mesh(**shape))


def test_basic_tp_fsdp():
    r = rules_for(data=16, model=16)
    assert r.spec_for(("embed", "heads", "head_dim"), (4096, 32, 128)) == \
        P("data", "model", None)
    assert r.spec_for(("embed", "ffn"), (4096, 14336)) == P("data", "model")


def test_heads_fallback_when_indivisible():
    """Arctic: 56 heads % 16 != 0 -> heads replicate (context-parallel)."""
    r = rules_for(data=16, model=16)
    assert r.spec_for(("embed", "heads", "head_dim"), (7168, 56, 128)) == \
        P("data", None, None)


def test_vocab_fallback_mamba():
    """Mamba-2 vocab 50280 % 16 != 0 -> embed dim picks up model axis."""
    r = rules_for(data=16, model=16)
    # vocab rule tries model and fails; embed falls back through data->...
    spec = r.spec_for(("vocab", "embed"), (50280, 2560))
    assert spec == P(None, "data")


def test_axis_used_once_per_tensor():
    r = rules_for(data=16, model=16)
    # both dims want 'model' (vocab + ffn-ish) - second one must skip it
    rules = dict(r.rules)
    rules["x1"] = ["model"]
    rules["x2"] = ["model", "data"]
    rr = ShardingRules(mesh=fake_mesh(data=16, model=16), rules=rules)
    assert rr.spec_for(("x1", "x2"), (32, 32)) == P("model", "data")


def test_kv_heads_replicate_when_small():
    r = rules_for(data=16, model=16)
    assert r.spec_for(("embed", "kv_heads", "head_dim"), (4096, 8, 128)) == \
        P("data", None, None)
    # 16 kv heads do shard
    assert r.spec_for(("embed", "kv_heads", "head_dim"), (1024, 16, 64)) == \
        P("data", "model", None)


def test_multipod_batch_axes():
    r = rules_for(pod=2, data=16, model=16)
    assert r.spec_for(("batch", "seq"), (256, 4096)) == \
        P(("pod", "data"), "model")
    # fsdp prefers the widest pod x data product when divisible
    assert r.spec_for(("embed", "ffn"), (8192, 29568)) == \
        P(("pod", "data"), "model")


def test_batch_one_replicates():
    r = rules_for(pod=2, data=16, model=16)
    assert r.spec_for(("batch", None), (1, 1)) == P(None, None)


def test_expert_sharding():
    r = rules_for(data=16, model=16)
    assert r.spec_for(("expert", "expert_embed", "expert_ffn"),
                      (128, 7168, 304)) == P("data", None, "model")
    # Mixtral virtualized to 16 sub-experts
    assert r.spec_for(("expert", "expert_embed", "expert_ffn"),
                      (16, 4096, 7168)) == P("data", None, "model")


def test_dp_only_strategy():
    r = make_rules(fake_mesh(data=16, model=16), strategy="dp_only")
    assert r.spec_for(("embed", "ffn"), (4096, 14336)) == P(None, None)
    assert r.spec_for(("batch", "seq"), (256, 4096)) == P("data", None)
