"""HLO analyzer: loop-aware FLOPs/bytes/collectives on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import analyze_hlo, roofline_terms


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    txt = _compile_text(lambda x, y: x @ y, a, b)
    got = analyze_hlo(txt)
    assert got["flops"] == 2 * 64 * 32 * 128


def test_scan_multiplies_by_trip_count():
    w = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def fn(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    got = analyze_hlo(_compile_text(fn, w, x))
    assert got["flops"] == 7 * 2 * 8 * 32 * 32
    assert got["unknown_trip_whiles"] == 0


def test_nested_scans_multiply():
    w = jax.ShapeDtypeStruct((3, 5, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)

    def fn(w, x):
        def outer(h, wo):
            def inner(hh, wi):
                return jnp.tanh(hh @ wi), None
            h2, _ = jax.lax.scan(inner, h, wo)
            return h2, None
        h, _ = jax.lax.scan(outer, x, w)
        return h

    got = analyze_hlo(_compile_text(fn, w, x))
    assert got["flops"] == 3 * 5 * 2 * 4 * 16 * 16


def test_hbm_bytes_positive_and_bounded():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    got = analyze_hlo(_compile_text(lambda x: x @ x, a))
    nbytes = 256 * 256 * 4
    assert got["hbm_bytes"] >= 3 * nbytes * 0.9  # two reads + one write
    assert got["hbm_bytes"] <= 30 * nbytes       # sane upper bound


def test_roofline_terms_structure():
    from repro.configs import SHAPES, get_config

    cfg = get_config("yi-6b")
    analysis = {"flops": 1e15, "hbm_bytes": 1e12,
                "collectives": {"total": 5e11}}
    t = roofline_terms(analysis, cfg, SHAPES["train_4k"], chips=256)
    assert t["dominant"] == "collective_s"
    assert t["compute_s"] == pytest.approx(1e15 / 197e12)
    assert 0 < t["roofline_fraction"] <= 2.0
    assert t["model_flops"] == pytest.approx(
        6.0 * cfg.params_active * 256 * 4096)
