"""Data pipeline: determinism, structure, prefetch."""
import numpy as np

from repro.data.pipeline import DataConfig, Prefetcher, make_batch


def test_deterministic_by_step():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
    a = make_batch(cfg, 7)
    b = make_batch(cfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_shapes_and_ranges():
    cfg = DataConfig(vocab_size=500, seq_len=32, global_batch=8)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == (8, 32) and b["labels"].shape == (8, 32)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 500
    # labels are next-token shifted
    raw_next = b["tokens"][:, 1:]
    np.testing.assert_array_equal(b["labels"][:, :-1], raw_next)


def test_ngram_structure_learnable():
    """Copy structure: labels repeat with lag -> better-than-chance
    predictability (this is what lets example losses actually fall)."""
    cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=4,
                     ngram_p=0.5, ngram_lag=2)
    b = make_batch(cfg, 0)
    t = b["tokens"]
    match = (t[:, 2:] == t[:, :-2]).mean()
    assert match > 0.3  # ~ngram_p plus collisions


def test_embeds_mode_for_frontend_stubs():
    cfg = DataConfig(vocab_size=504, seq_len=16, global_batch=2,
                     embed_dim=128)
    b = make_batch(cfg, 0)
    assert b["embeds"].shape == (2, 16, 128)
    assert b["labels"].shape == (2, 16)


def test_host_sharding_disjoint():
    full = DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                      num_hosts=2, host_id=0)
    other = DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                       num_hosts=2, host_id=1)
    a, b = make_batch(full, 0), make_batch(other, 0)
    assert a["tokens"].shape == (4, 8)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_prefetcher_orders_batches():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    pf = Prefetcher(cfg, start_step=5, depth=2)
    try:
        for expect in (5, 6, 7):
            step, batch = next(pf)
            assert step == expect
            np.testing.assert_array_equal(batch["tokens"],
                                          make_batch(cfg, step)["tokens"])
    finally:
        pf.close()
