"""Multi-device semantics, each case in a subprocess with 8 host devices.

(The main pytest process must keep the default 1-device CPU runtime, so
anything needing a mesh larger than 1 runs via a child interpreter.)
"""
import os
import subprocess
import sys

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_csp_backend_multidevice():
    out = run_sub("""
import numpy as np
from repro.core import make_graph, check_outputs
from repro.backends import get_backend
for pat, kw in [("stencil", {}), ("spread", {"radix": 5}), ("fft", {})]:
    g = make_graph(width=16, height=8, pattern=pat, iterations=4,
                   output_bytes=64, **kw)
    be = get_backend("shardmap-csp")
    assert be.ndev == 8
    check_outputs(g, be.run([g])[0])
print("CSP8OK")
""")
    assert "CSP8OK" in out


def test_moe_a2a_matches_dense():
    out = run_sub("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.dist.sharding import make_rules, use_rules
from repro.models import moe as MO
from repro.models.layers import split_leaves
import dataclasses

cfg = reduced(get_config("mixtral-8x7b"))
cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh)
p_leaf = MO.init_moe(jax.random.PRNGKey(0), cfg)
params, _ = split_leaves(p_leaf)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32)

y_dense, m1 = MO.apply_moe(params, x, cfg, impl="dense")
with mesh, use_rules(rules):
    y_a2a, m2 = jax.jit(lambda p, xx: MO.apply_moe(p, xx, cfg, impl="a2a"))(params, x)
err = np.abs(np.asarray(y_a2a) - np.asarray(y_dense)).max()
scale = np.abs(np.asarray(y_dense)).max()
print("moe err", err, "scale", scale)
assert err < 5e-4 * max(scale, 1), err
assert abs(float(m1["moe_lb_loss"]) - float(m2["moe_lb_loss"])) < 1e-3
print("MOEOK")
""")
    assert "MOEOK" in out


def test_moe_a2a_grads_match_dense():
    out = run_sub("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.dist.sharding import make_rules, use_rules
from repro.models import moe as MO
from repro.models.layers import split_leaves
import dataclasses

cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                          moe_capacity_factor=8.0)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh)
params, _ = split_leaves(MO.init_moe(jax.random.PRNGKey(0), cfg))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))

def loss(impl):
    def f(p):
        y, _ = MO.apply_moe(p, x, cfg, impl=impl)
        return (y.astype(jnp.float32) ** 2).mean()
    return f

g_dense = jax.grad(loss("dense"))(params)
with mesh, use_rules(rules):
    g_a2a = jax.jit(jax.grad(loss("a2a")))(params)
for k in ("w_gate", "w_up", "w_down"):
    a, b = np.asarray(g_a2a[k], np.float32), np.asarray(g_dense[k], np.float32)
    np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-5)
print("MOEGRADOK")
""")
    assert "MOEGRADOK" in out


def test_compressed_psum():
    out = run_sub("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.dist.compression import compressed_psum
mesh = jax.make_mesh((8,), ("d",))
x = np.random.RandomState(0).randn(8, 64).astype(np.float32)
f = jax.jit(shard_map(lambda v: compressed_psum(v, "d"),
    mesh=mesh, in_specs=P("d"), out_specs=P("d")))
got = np.asarray(f(jnp.asarray(x)))
want = x.sum(0, keepdims=True)
scale = np.abs(x).max() / 127.0
assert np.abs(got - want).max() <= 8 * scale * 0.51 + 1e-6
print("PSUMOK")
""")
    assert "PSUMOK" in out


def test_dryrun_machinery_tiny_mesh():
    """The dry-run driver end-to-end on a (2,2,2) pod mesh, reduced arch."""
    out = run_sub("""
import jax, jax.numpy as jnp, functools
from repro.configs import get_config, reduced, SHAPES, InputShape
from repro.dist.sharding import make_rules, use_rules
from repro.launch import specs as SP
from repro.launch.roofline import analyze_hlo
from repro.optim import adamw
from repro.train import train_step as TS

cfg = reduced(get_config("yi-6b"))
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = make_rules(mesh)
shape = InputShape("tiny_train", 64, 8, "train")
with mesh, use_rules(rules):
    tcfg = TS.TrainConfig(grad_accum=2, adamw=adamw.AdamWConfig())
    state, axes = SP.state_struct(cfg, tcfg)
    st_sh = SP.shardings_from_axes(axes, state, rules)
    batch, baxes = SP.batch_struct(cfg, shape)
    b_sh = SP.shardings_from_axes(baxes, batch, rules)
    fn = functools.partial(TS.train_step, cfg=cfg, tcfg=tcfg)
    compiled = jax.jit(fn, donate_argnums=(0,), in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None)).lower(state, batch).compile()
mem = compiled.memory_analysis()
a = analyze_hlo(compiled.as_text())
assert a["flops"] > 0 and a["collectives"]["total"] > 0
print("DRYRUNOK", mem.temp_size_in_bytes, int(a["flops"]))
""", devices=8)
    assert "DRYRUNOK" in out
