"""Multi-device semantics, each case in a subprocess with 8 host devices.

(The main pytest process must keep the default 1-device CPU runtime, so
anything needing a mesh larger than 1 runs via a child interpreter.)
"""
import os
import subprocess
import sys

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_csp_backend_multidevice():
    out = run_sub("""
import numpy as np
from repro.core import make_graph, check_outputs
from repro.backends import get_backend
for pat, kw in [("stencil", {}), ("spread", {"radix": 5}), ("fft", {})]:
    g = make_graph(width=16, height=8, pattern=pat, iterations=4,
                   output_bytes=64, **kw)
    be = get_backend("shardmap-csp")
    assert be.ndev == 8
    check_outputs(g, be.run([g])[0])
print("CSP8OK")
""")
    assert "CSP8OK" in out


def test_backend_conformance_8dev():
    """The conformance matrix (every backend x every pattern) on 8 ranks."""
    out = run_sub("""
from repro.core import make_graph, check_outputs, execute_reference, pattern_names
from repro.backends import backend_names, get_backend
assert "shardmap-pipeline" in backend_names()
for pattern in pattern_names():
    kw = {"radix": 3} if pattern in ("nearest", "spread") else {}
    g = make_graph(width=8, height=6, pattern=pattern, iterations=3, **kw)
    expected = execute_reference(g)
    for be in backend_names():
        check_outputs(g, get_backend(be).run([g])[0], expected=expected)
print("CONFORM8OK")
""")
    assert "CONFORM8OK" in out


def test_ragged_width_multidevice():
    """Paper's MPI handles ragged columns: width 10 on 4 ranks, and a
    width smaller than the rank count (dead ranks)."""
    out = run_sub("""
import numpy as np, jax
from jax.sharding import Mesh
from repro.core import make_graph, check_outputs
from repro.backends import get_backend

mesh4 = Mesh(np.array(jax.devices()[:4]), ("cols",))
for pat, kw in [("stencil", {}), ("spread", {"radix": 3})]:
    g = make_graph(width=10, height=8, pattern=pat, iterations=4, **kw)
    be = get_backend("shardmap-csp", mesh=mesh4)
    plan = be.plan(g)
    assert plan.ragged and plan.padded_width == 12, plan
    check_outputs(g, be.run([g])[0])

# width 4 over 8 ranks: half the ranks hold only dead columns
g = make_graph(width=4, height=6, pattern="random", iterations=3)
check_outputs(g, get_backend("shardmap-csp").run([g])[0])
check_outputs(g, get_backend("shardmap-pipeline").run([g])[0])
print("RAGGEDOK")
""")
    assert "RAGGEDOK" in out


def test_run_many_combined_program_8dev():
    """The combined multi-graph shard_map program (one scan interleaving
    every graph's exchange+timestep) on 8 real ranks, ragged width, all
    three comm modes — bit-exact against single-graph runs."""
    out = run_sub("""
import numpy as np
from repro.core import make_graph, replicate, check_outputs, execute_reference
from repro.backends import get_backend
for bn in ("shardmap-csp", "shardmap-pipeline"):
    be = get_backend(bn)
    assert be.ndev == 8
    for pattern, kw in (("stencil", {}), ("sweep", {}),
                        ("spread", {"radix": 3})):
        g = make_graph(width=10, height=8, pattern=pattern, iterations=4, **kw)
        expected = execute_reference(g)
        alone = np.asarray(be.run([g])[0])
        outs = be.run_many(replicate(g, 3))
        assert len(outs) == 3
        for o in outs:
            check_outputs(g, o, expected=expected)
            assert (np.asarray(o)[:, :4] == alone[:, :4]).all()
print("RUNMANY8OK")
""")
    assert "RUNMANY8OK" in out


def test_pipeline_backend_ring_8dev():
    """Sweep-class graphs ride the one-directional ppermute ring."""
    out = run_sub("""
from repro.core import make_graph, check_outputs
from repro.backends import get_backend
be = get_backend("shardmap-pipeline")
assert be.ndev == 8
for width in (8, 16):
    g = make_graph(width=width, height=10, pattern="sweep", iterations=4,
                   output_bytes=64)
    plan = be.plan(g)
    assert plan.mode == "ring", plan.mode
    check_outputs(g, be.run([g])[0])
print("RING8OK")
""")
    assert "RING8OK" in out


def test_pp_forward_4d_mesh():
    """pp_forward through a (pod, data, model, stage) mesh == reference."""
    out = run_sub("""
import dataclasses, jax, numpy as np
from repro.configs import get_config, reduced
from repro.dist import pipeline as PP
from repro.dist.sharding import make_rules, use_rules
from repro.models import model as M
from repro.models.layers import split_leaves

cfg = dataclasses.replace(reduced(get_config("yi-6b")), num_layers=4)
params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
ref_logits, _, _ = M.forward(params, cfg, tokens=toks)

mesh = jax.make_mesh((1, 2, 2, 2), ("pod", "data", "model", "stage"))
rules = make_rules(mesh)
pp_params = PP.stack_params_by_stage(params, num_stages=2)
with mesh, use_rules(rules):
    pp_logits = jax.jit(
        lambda p, t: PP.pp_forward(p, cfg, t, 2, 4))(pp_params, toks)
np.testing.assert_allclose(np.asarray(pp_logits, np.float32),
                           np.asarray(ref_logits, np.float32),
                           rtol=2e-3, atol=2e-3)
print("PP4DOK")
""")
    assert "PP4DOK" in out


def test_dp_train_step_8dev():
    """shard_map'd DP step == reference step; compressed within tolerance."""
    out = run_sub("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, make_batch
from repro.train import train_step as TS, dist_step as DS

cfg = reduced(get_config("qwen1.5-0.5b"))
tcfg = TS.TrainConfig(base_lr=1e-3, warmup_steps=2, total_steps=40)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=16)
mesh = jax.make_mesh((8,), ("data",))

def run(fn, steps=3):
    state, _ = TS.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    losses = []
    for s in range(steps):
        state, m = fn(state, make_batch(dcfg, s))
        losses.append(float(m["loss"]))
    return state, losses

s_ref, l_ref = run(TS.jit_train_step(cfg, tcfg))
s_ex, l_ex = run(DS.jit_dp_train_step(cfg, tcfg, mesh, compress=False))
s_c, l_c = run(DS.jit_dp_train_step(cfg, tcfg, mesh, compress=True))
np.testing.assert_allclose(l_ex, l_ref, atol=1e-4)
np.testing.assert_allclose(l_c, l_ref, atol=2e-2)
for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_ex.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_c.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-2)
print("DPSTEP8OK")
""")
    assert "DPSTEP8OK" in out


def test_csp_forced_a2a_8dev():
    """The per-pair a2a exchange (CommPlan mode "a2a") on 8 real ranks,
    including ragged width and local>1 blocks."""
    out = run_sub("""
from repro.core import make_graph, check_outputs
from repro.backends import get_backend
be = get_backend("shardmap-csp", comm="a2a")
assert be.ndev == 8
for pat, kw, width in [("stencil", {}, 16), ("spread", {"radix": 3}, 10),
                       ("fft", {}, 16), ("sweep", {}, 4)]:
    g = make_graph(width=width, height=8, pattern=pat, iterations=4, **kw)
    plan = be.plan(g)
    assert plan.mode == "a2a"
    assert (plan.recv_counts == plan.send_counts.T).all()
    check_outputs(g, be.run([g])[0])
print("A2A8OK")
""")
    assert "A2A8OK" in out


def test_moe_sp_matches_replicated_8rank():
    """SP-aware EP == token replication == dense on an 8-rank (data x
    model) mesh — forward and parameter gradients — and the explicit
    ep_mode plumbing through apply_moe/cfg agrees with the config
    default (mixtral ships ep_mode="sp")."""
    out = run_sub("""
import dataclasses, jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.dist.sharding import make_rules, use_rules
from repro.models import moe as MO
from repro.models.layers import split_leaves

cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                          moe_capacity_factor=8.0)
assert cfg.ep_mode == "sp"
for shape in ((4, 2), (2, 4)):
    mesh = jax.make_mesh(shape, ("data", "model"))
    rules = make_rules(mesh)
    params, _ = split_leaves(MO.init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                          jnp.float32)
    y_dense, m_d = MO.apply_moe(params, x, cfg, impl="dense")
    with mesh, use_rules(rules):
        run = lambda mode: jax.jit(lambda p, xx: MO.apply_moe(
            p, xx, cfg, impl="a2a", ep_mode=mode))(params, x)
        y_sp, m_sp = run("sp")
        y_rep, m_rep = run("replicated")
        y_cfg, _ = jax.jit(lambda p, xx: MO.apply_moe(
            p, xx, cfg, impl="a2a"))(params, x)  # cfg default -> sp
    scale = np.abs(np.asarray(y_dense)).max()
    tol = 5e-4 * max(scale, 1)  # same tolerance as test_moe_a2a_matches_dense
    assert np.abs(np.asarray(y_sp) - np.asarray(y_rep)).max() < tol, shape
    assert np.abs(np.asarray(y_sp) - np.asarray(y_dense)).max() < tol, shape
    assert np.abs(np.asarray(y_cfg) - np.asarray(y_sp)).max() == 0.0, shape
    assert abs(float(m_sp["moe_lb_loss"]) - float(m_rep["moe_lb_loss"])) < 1e-3

    def loss(impl, mode=None):
        def f(p):
            y, _ = MO.apply_moe(p, x, cfg, impl=impl, ep_mode=mode)
            return (y.astype(jnp.float32) ** 2).mean()
        return f
    g_dense = jax.grad(loss("dense"))(params)
    with mesh, use_rules(rules):
        g_sp = jax.jit(jax.grad(loss("a2a", "sp")))(params)
    for k in ("w_gate", "w_up", "w_down"):
        np.testing.assert_allclose(np.asarray(g_sp[k], np.float32),
                                   np.asarray(g_dense[k], np.float32),
                                   rtol=5e-3, atol=5e-5)
print("MOESPOK")
""")
    assert "MOESPOK" in out


def test_moe_dispatch_roofline_8dev():
    """Acceptance gate: the compiled MoE program's per-plane all-to-all
    bytes (dry-run roofline over optimized HLO) drop by exactly |model|
    under SP-aware EP, and match the analytic capacity model."""
    out = run_sub("""
from repro.bench import MoEDispatchSpec, moe_dispatch_report
for data, model in ((4, 2), (2, 4)):
    reps = {}
    for ep_mode in ("replicated", "sp"):
        spec = MoEDispatchSpec(data=data, model=model, ep_mode=ep_mode)
        rep = moe_dispatch_report(spec, compiled=True)
        # the compiled program moves exactly the planned bytes
        assert rep["hlo_a2a_bytes"] == rep["a2a_bytes"], (ep_mode, rep)
        reps[ep_mode] = rep
    # per-plane a2a volume reduced by the model axis size
    assert reps["replicated"]["hlo_a2a_bytes"] == \\
        reps["sp"]["hlo_a2a_bytes"] * model, (data, model)
    # sp trades the duplicated a2a for one over-model all-gather
    assert reps["sp"]["hlo_allgather_bytes"] > 0
    assert reps["replicated"]["hlo_allgather_bytes"] == 0
print("MOEDISPATCHOK")
""")
    assert "MOEDISPATCHOK" in out


def test_moe_a2a_matches_dense():
    out = run_sub("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.dist.sharding import make_rules, use_rules
from repro.models import moe as MO
from repro.models.layers import split_leaves
import dataclasses

cfg = reduced(get_config("mixtral-8x7b"))
cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh)
p_leaf = MO.init_moe(jax.random.PRNGKey(0), cfg)
params, _ = split_leaves(p_leaf)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32)

y_dense, m1 = MO.apply_moe(params, x, cfg, impl="dense")
with mesh, use_rules(rules):
    y_a2a, m2 = jax.jit(lambda p, xx: MO.apply_moe(p, xx, cfg, impl="a2a"))(params, x)
err = np.abs(np.asarray(y_a2a) - np.asarray(y_dense)).max()
scale = np.abs(np.asarray(y_dense)).max()
print("moe err", err, "scale", scale)
assert err < 5e-4 * max(scale, 1), err
assert abs(float(m1["moe_lb_loss"]) - float(m2["moe_lb_loss"])) < 1e-3
print("MOEOK")
""")
    assert "MOEOK" in out


def test_moe_a2a_grads_match_dense():
    out = run_sub("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.dist.sharding import make_rules, use_rules
from repro.models import moe as MO
from repro.models.layers import split_leaves
import dataclasses

cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                          moe_capacity_factor=8.0)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh)
params, _ = split_leaves(MO.init_moe(jax.random.PRNGKey(0), cfg))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))

def loss(impl):
    def f(p):
        y, _ = MO.apply_moe(p, x, cfg, impl=impl)
        return (y.astype(jnp.float32) ** 2).mean()
    return f

g_dense = jax.grad(loss("dense"))(params)
with mesh, use_rules(rules):
    g_a2a = jax.jit(jax.grad(loss("a2a")))(params)
for k in ("w_gate", "w_up", "w_down"):
    a, b = np.asarray(g_a2a[k], np.float32), np.asarray(g_dense[k], np.float32)
    np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-5)
print("MOEGRADOK")
""")
    assert "MOEGRADOK" in out


def test_compressed_psum():
    out = run_sub("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.dist.compression import compressed_psum
mesh = jax.make_mesh((8,), ("d",))
x = np.random.RandomState(0).randn(8, 64).astype(np.float32)
f = jax.jit(shard_map(lambda v: compressed_psum(v, "d"),
    mesh=mesh, in_specs=P("d"), out_specs=P("d")))
got = np.asarray(f(jnp.asarray(x)))
want = x.sum(0, keepdims=True)
scale = np.abs(x).max() / 127.0
assert np.abs(got - want).max() <= 8 * scale * 0.51 + 1e-6
print("PSUMOK")
""")
    assert "PSUMOK" in out


def test_dryrun_machinery_tiny_mesh():
    """The dry-run driver end-to-end on a (2,2,2) pod mesh, reduced arch."""
    out = run_sub("""
import jax, jax.numpy as jnp, functools
from repro.configs import get_config, reduced, SHAPES, InputShape
from repro.dist.sharding import make_rules, use_rules
from repro.launch import specs as SP
from repro.launch.roofline import analyze_hlo
from repro.optim import adamw
from repro.train import train_step as TS

cfg = reduced(get_config("yi-6b"))
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = make_rules(mesh)
shape = InputShape("tiny_train", 64, 8, "train")
with mesh, use_rules(rules):
    tcfg = TS.TrainConfig(grad_accum=2, adamw=adamw.AdamWConfig())
    state, axes = SP.state_struct(cfg, tcfg)
    st_sh = SP.shardings_from_axes(axes, state, rules)
    batch, baxes = SP.batch_struct(cfg, shape)
    b_sh = SP.shardings_from_axes(baxes, batch, rules)
    fn = functools.partial(TS.train_step, cfg=cfg, tcfg=tcfg)
    compiled = jax.jit(fn, donate_argnums=(0,), in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None)).lower(state, batch).compile()
mem = compiled.memory_analysis()
a = analyze_hlo(compiled.as_text())
assert a["flops"] > 0 and a["collectives"]["total"] > 0
print("DRYRUNOK", mem.temp_size_in_bytes, int(a["flops"]))
""", devices=8)
    assert "DRYRUNOK" in out
