"""Declarative suite orchestrator (`repro.bench.suite` + CLI) and the
bench-CLI bugfix contracts that campaigns amplify:

* TOML parse/validate negative paths name the offending entry and exit
  nonzero before anything runs,
* a failed cell fails the suite but the remaining cells still complete,
* `parallel > 1` writes artifacts bit-identical to serial `run.py` runs
  (synthetic timer),
* every registry module's ``run`` accepts zero args,
* `--tables` never splices a partial artifact set, and corrupt-artifact
  skips are warned and counted.
"""
from __future__ import annotations

import inspect
import os

import pytest

from repro.bench.suite import (CellRun, Suite, SuiteCell, _compare_rollout,
                               cell_command, load_suite, parse_suite,
                               run_suite, validate_suite)

FAMILIES = ["bench_peak", "bench_metg_deps", "bench_metg_scaling"]


# ---------------------------------------------------------- parse errors
def test_parse_suite_rejects_bad_toml():
    with pytest.raises(ValueError, match="not valid TOML"):
        parse_suite("name = ", source="x.toml")
    with pytest.raises(ValueError, match="unknown top-level key"):
        parse_suite('name="s"\nparallell=2\n[[tasks]]\nfamily="bench_peak"')
    with pytest.raises(ValueError, match=r"\[\[tasks\]\] cell"):
        parse_suite('name="s"')
    with pytest.raises(ValueError, match="entry #2.*unknown key"):
        parse_suite('name="s"\n[[tasks]]\nfamily="bench_peak"\n'
                    '[[tasks]]\nfamily="bench_metg_deps"\nrolouts=2')
    with pytest.raises(ValueError, match="rollouts must be >= 1"):
        parse_suite('name="s"\n[[tasks]]\nfamily="bench_peak"\nrollouts=0')
    with pytest.raises(ValueError, match="unknown timer"):
        parse_suite('name="s"\n[[tasks]]\nfamily="bench_peak"\n'
                    'timer="cpu-cycles"')
    with pytest.raises(ValueError, match="backends must be a list"):
        parse_suite('name="s"\n[[tasks]]\nfamily="bench_peak"\n'
                    'backends="xla-scan"')
    with pytest.raises(ValueError, match=r"backends = \[\]"):
        parse_suite('name="s"\n[[tasks]]\nfamily="bench_peak"\n'
                    'backends=[]')
    with pytest.raises(ValueError, match="needs a name"):
        parse_suite('[[tasks]]\nfamily="bench_peak"')


def test_parse_suite_timer_inheritance():
    s = parse_suite('name="s"\ntimer="wallclock"\n'
                    '[[tasks]]\nfamily="bench_peak"\n'
                    '[[tasks]]\nfamily="bench_metg_deps"\n'
                    'timer="synthetic"')
    assert s.cell_timer(s.cells[0]) == "wallclock"
    assert s.cell_timer(s.cells[1]) == "synthetic"
    assert s.parallel == 1  # default


def test_validate_suite_names_the_entry():
    s = Suite(name="s", cells=(SuiteCell(family="bench_peak"),
                               SuiteCell(family="bench_typo")))
    with pytest.raises(ValueError, match="entry #2.*bench_typo"):
        validate_suite(s, known_families=FAMILIES)
    s = Suite(name="s", cells=(SuiteCell(family="bench_peak"),
                               SuiteCell(family="bench_peak")))
    with pytest.raises(ValueError, match="duplicate family"):
        validate_suite(s, known_families=FAMILIES)
    s = Suite(name="s", cells=(
        SuiteCell(family="bench_peak", backends=("not-a-backend",)),))
    with pytest.raises(ValueError, match="unknown backend 'not-a-backend'"):
        validate_suite(s, known_families=FAMILIES,
                       known_backends=["xla-scan"])
    # option brackets are spec syntax, not registry keys
    s = Suite(name="s", cells=(
        SuiteCell(family="bench_peak",
                  backends=("xla-scan", "auto[exclude=host-dynamic]")),))
    validate_suite(s, known_families=FAMILIES, known_backends=["xla-scan"])


def test_cell_command_is_the_serial_cli():
    suite = parse_suite('name="s"\ntimer="synthetic"\n'
                        '[[tasks]]\nfamily="bench_metg_scaling"\n'
                        'backends=["shardmap-csp", "auto"]')
    cmd = cell_command(suite, suite.cells[0], "/tmp/out", smoke=True,
                       python="PY")
    assert cmd == ["PY", "-m", "benchmarks.run",
                   "--only", "bench_metg_scaling",
                   "--artifacts", "/tmp/out",
                   "--timer", "synthetic", "--smoke",
                   "--backends", "shardmap-csp,auto"]


# ------------------------------------------------------- rollout compare
def test_compare_rollout_flags_byte_drift(tmp_path):
    primary, roll = tmp_path / "out", tmp_path / "out" / "r1"
    roll.mkdir(parents=True)
    (primary / "BENCH_x.a.json").write_text('{"v": 1}')
    (roll / "BENCH_x.a.json").write_text('{"v": 1}')
    run = CellRun(cell=SuiteCell(family="bench_peak"), out_dir=str(roll),
                  rollout=1, returncode=0, stdout="", stderr="")
    assert _compare_rollout(str(primary), run) == []
    (roll / "BENCH_x.a.json").write_text('{"v": 2}')
    bad = _compare_rollout(str(primary), run)
    assert len(bad) == 1 and "differs byte-wise" in bad[0][1]
    (roll / "BENCH_x.b.json").write_text("{}")
    assert any("only in the rollout" in d for _, d in
               _compare_rollout(str(primary), run))
    for f in roll.iterdir():
        f.unlink()
    assert any("no BENCH" in d for _, d in
               _compare_rollout(str(primary), run))


# --------------------------------------------------------- CLI + e2e runs
def test_suite_cli_exit2_on_validation(tmp_path, capsys):
    from benchmarks.suite import main

    bad = tmp_path / "bad.toml"
    bad.write_text('name="x"\n[[tasks]]\nfamily="bench_nope"\n')
    with pytest.raises(SystemExit) as exc:
        main([str(bad), "--smoke", "--artifacts", str(tmp_path / "out")])
    assert exc.value.code == 2
    assert "bench_nope" in capsys.readouterr().err
    # nothing ran, nothing written
    assert not (tmp_path / "out").exists()
    with pytest.raises(SystemExit) as exc:
        main([str(tmp_path / "missing.toml"), "--smoke"])
    assert exc.value.code == 2


def test_suite_parallel_artifacts_bit_identical_to_serial(tmp_path, capsys):
    """The acceptance contract: a parallel campaign's artifacts are
    byte-for-byte the files serial `run.py --smoke` writes (synthetic)."""
    from benchmarks.run import main as run_main
    from benchmarks.suite import main as suite_main

    toml = tmp_path / "s.toml"
    toml.write_text('name="tiny"\nparallel=2\ntimer="synthetic"\n'
                    '[[tasks]]\nfamily="bench_peak"\n'
                    '[[tasks]]\nfamily="bench_metg_deps"\nrollouts=2\n')
    suite_dir = tmp_path / "suite"
    suite_main([str(toml), "--smoke", "--artifacts", str(suite_dir)])
    out = capsys.readouterr().out
    assert "suite 'tiny': 3 cell run(s), all ok" in out
    serial_dir = tmp_path / "serial"
    for fam in ("bench_peak", "bench_metg_deps"):
        run_main(["--smoke", "--timer", "synthetic", "--only", fam,
                  "--artifacts", str(serial_dir)])
    capsys.readouterr()
    serial = sorted(os.listdir(serial_dir))
    assert serial == sorted(f for f in os.listdir(suite_dir)
                            if f != "rollouts")
    for f in serial:
        assert ((serial_dir / f).read_bytes()
                == (suite_dir / f).read_bytes()), f


def test_suite_failed_cell_completes_remaining(tmp_path, capsys):
    """A red cell (backends filter matching nothing) exits the suite
    nonzero but the other cells still run and write artifacts."""
    from benchmarks.suite import main as suite_main

    toml = tmp_path / "s.toml"
    toml.write_text('name="redgreen"\ntimer="synthetic"\n'
                    '[[tasks]]\nfamily="bench_metg_scaling"\n'
                    'backends=["xla-scan"]\n'
                    '[[tasks]]\nfamily="bench_peak"\n')
    with pytest.raises(SystemExit) as exc:
        suite_main([str(toml), "--smoke",
                    "--artifacts", str(tmp_path / "out")])
    assert exc.value.code == 1
    out = capsys.readouterr().out
    assert "FAILED bench_metg_scaling" in out
    assert "bench_peak: ok" in out
    assert any(f.startswith("BENCH_peak") for f in
               os.listdir(tmp_path / "out"))


def test_paper_suite_toml_is_valid():
    """The committed campaign document stays loadable and covers every
    registry family exactly once."""
    from benchmarks.run import MODULES

    suite = load_suite(os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks", "suites", "paper.toml"))
    validate_suite(suite, known_families=MODULES)
    assert sorted(c.family for c in suite.cells) == sorted(MODULES)
    assert suite.timer == "synthetic" and suite.parallel > 1
    assert any(c.rollouts > 1 for c in suite.cells)


# ------------------------------------------- registry + tables bug fixes
def test_every_registry_module_runs_with_zero_args():
    """bench_serve_load:38 regression: every MODULES entry's ``run`` must
    be invocable standalone (all parameters defaulted)."""
    from benchmarks.run import MODULES

    for name in MODULES:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        sig = inspect.signature(mod.run)
        missing = [p.name for p in sig.parameters.values()
                   if p.default is inspect.Parameter.empty
                   and p.kind not in (inspect.Parameter.VAR_POSITIONAL,
                                      inspect.Parameter.VAR_KEYWORD)]
        assert not missing, (
            f"{name}.run requires arguments {missing}; standalone "
            f"invocation (no BenchContext) must work for every module")


def test_tables_splice_skipped_on_red_run(tmp_path, capsys):
    """run.py must not regenerate committed tables from a partial
    artifact set: a failed module skips --tables with a stderr note."""
    from benchmarks.run import main

    md = tmp_path / "EXP.md"
    with pytest.raises(SystemExit) as exc:
        main(["--smoke", "--timer", "synthetic",
              "--only", "bench_metg_scaling,bench_peak",
              "--backends", "xla-scan",  # matches nothing -> module fails
              "--artifacts", str(tmp_path), "--tables",
              "--tables-file", str(md)])
    assert exc.value.code == 1
    captured = capsys.readouterr()
    assert "skipping --tables" in captured.err
    assert not md.exists()


def test_load_metg_artifacts_warns_and_counts_skips(tmp_path, capsys):
    """Corrupt/foreign artifacts must not vanish silently from the
    tables: each skip warns naming path + reason, and the count comes
    back to the caller."""
    import append_tables
    from benchmarks.run import main

    main(["--smoke", "--timer", "synthetic", "--only", "bench_peak",
          "--artifacts", str(tmp_path)])
    capsys.readouterr()
    (tmp_path / "BENCH_truncated.json").write_text('{"schema": 1, "ki')
    docs, skipped = append_tables.load_metg_artifacts(str(tmp_path))
    err = capsys.readouterr().err
    assert docs and skipped == 1
    assert "BENCH_truncated.json" in err and "not valid JSON" in err
    # the count propagates through append_metg_tables
    md = tmp_path / "EXP.md"
    path, skipped = append_tables.append_metg_tables(str(tmp_path), str(md))
    assert path == str(md) and skipped == 1
    assert "METG(50%)" in md.read_text()
