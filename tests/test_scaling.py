"""metg_scaling: the weak-scaling family (paper §V-D/E).

Covers the three layers separately so failures localize:

* the ``SyntheticTimer`` rank-count model (closed-form assertions — the
  charged wall time is a pure function of ``(graph, ranks, spec)``),
* the ``kind="metg_scaling"`` artifact schema incl. corruption
  rejection, and the ``compare`` gate branch,
* the subprocess rank launcher end to end (ranks {1, 2} on the
  synthetic timer — deterministic, so exact cross-process equality).
"""
from __future__ import annotations

import copy
import json
import os

import pytest

from repro.bench import SyntheticTimer, validate_artifact
from repro.bench.compare import compare_artifacts
from repro.bench.scaling import (SCALING_BACKENDS, ScalingSpec, rank_env,
                                 run_rank_cell, run_scaling,
                                 scaling_artifact, write_scaling_json)
from repro.bench.timers import backend_comm_hints
from repro.core.graph import TaskGraph

SYNTH = {"name": "synthetic", "config": {}}


# ----------------------------------------------------- rank model (timers)
def test_backend_comm_hints_resolve_by_name_only():
    assert backend_comm_hints("shardmap-csp") == (False, False)
    assert backend_comm_hints("shardmap-csp[comm=onesided]") == (True, False)
    assert backend_comm_hints("shardmap-csp[comm_overlap=true]") == (False,
                                                                     True)
    # malformed specs fall back to blocking two-sided, never raise
    assert backend_comm_hints("no [such] backend!!") == (False, False)


def test_ranked_model_trivial_pattern_is_block_makespan():
    """No dependencies -> no comm; uniform tasks split into equal static
    blocks, so the wall time is height * (width/ranks) * per-task cost."""
    t = SyntheticTimer(ranks=4, seconds_per_byte=1e-9,
                      seconds_per_rendezvous=1e-6)
    g = TaskGraph(width=8, height=5, pattern="trivial")
    per_task = (t.overhead_per_task
                + g.task_iterations(0, 0) * t.seconds_per_iteration)
    expected = 5 * 2 * per_task  # 2 columns per rank
    assert t.measure("shardmap-csp", [g]) == pytest.approx(expected)


def test_ranked_model_charges_only_cross_rank_deps():
    """Stencil deps at a rank boundary pay the message cost; the same
    graph at ranks=1 pays nothing (everything is rank-local)."""
    g = TaskGraph(width=8, height=4, pattern="stencil", output_bytes=1024)
    kw = dict(seconds_per_byte=1e-9, seconds_per_rendezvous=5e-6)
    t1 = SyntheticTimer(ranks=1, **kw)
    t2 = SyntheticTimer(ranks=2, **kw)
    per_task = (t1.overhead_per_task
                + g.task_iterations(0, 0) * t1.seconds_per_iteration)
    # ranks=1: pure compute, sequential over all 32 tasks
    assert t1.measure("shardmap-csp", [g]) == pytest.approx(32 * per_task)
    # ranks=2: boundary columns 3<->4 exchange across the cut; stencil
    # (radius 1) crosses it twice per timestep except t=0 (no deps)
    import numpy as np

    from repro.core.schedule import static_owners

    owners = static_owners(8, 2)
    cross = int((g.dependence_matrices()
                 & (owners[None, :, None] != owners[None, None, :])).sum())
    assert cross == 3 * 2
    per_dep = kw["seconds_per_byte"] * 1024 + kw["seconds_per_rendezvous"]
    expected = 4 * 4 * per_task + cross * per_dep  # blocking: compute + comm
    assert t2.measure("shardmap-csp", [g]) == pytest.approx(expected)
    # onesided: no rendezvous surcharge, and comm overlaps compute
    t2o = SyntheticTimer(ranks=2, **kw)
    comm = cross * kw["seconds_per_byte"] * 1024
    assert t2o.measure("shardmap-csp[comm=onesided]", [g]) == pytest.approx(
        max(4 * 4 * per_task, comm))


def test_rank_model_off_by_default():
    """ranks=0 (the default) must leave every existing family's charged
    model untouched."""
    g = TaskGraph(width=4, height=4, pattern="trivial")
    assert (SyntheticTimer().measure("xla-scan", [g])
            == SyntheticTimer(ranks=0).measure("xla-scan", [g]))


# ------------------------------------------------------------ spec checks
def test_scaling_spec_validation():
    with pytest.raises(ValueError, match="ascending"):
        ScalingSpec(name="s", ranks=(1, 4, 2))
    with pytest.raises(ValueError, match="include 1"):
        ScalingSpec(name="s", ranks=(2, 4))
    with pytest.raises(ValueError, match="non-empty"):
        ScalingSpec(name="s", ranks=())
    with pytest.raises(ValueError, match="needs a name"):
        ScalingSpec(name="")
    spec = ScalingSpec(name="s", ranks=(1, 2))
    sc = spec.scenario_for(2, smoke=True)
    assert sc.width == 2 * spec.width_per_rank
    assert sc.name == "s.r2"
    with pytest.raises(ValueError, match="not in"):
        spec.scenario_for(8)


def test_rank_env_pins_device_count_and_strips_inherited():
    base = {"JAX_NUM_CPU_DEVICES": "8",
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8 "
                         "--xla_dump_to=/tmp/d",
            "PYTHONPATH": "/elsewhere"}
    env = rank_env(4, base)
    # exactly one device-count knob, set to the child's rank count
    pinned = [env.get("JAX_NUM_CPU_DEVICES"),
              *[f.split("=")[1] for f in env.get("XLA_FLAGS", "").split()
                if f.startswith("--xla_force_host_platform_device_count")]]
    assert [p for p in pinned if p is not None] == ["4"]
    # unrelated XLA flags survive; the checkout's src leads PYTHONPATH
    assert "--xla_dump_to=/tmp/d" in env.get("XLA_FLAGS", "")
    first = env["PYTHONPATH"].split(os.pathsep)[0]
    assert os.path.isdir(os.path.join(first, "repro"))
    assert "/elsewhere" in env["PYTHONPATH"].split(os.pathsep)


# ------------------------------------------------- artifact schema + gate
def _cells(spec, ranks=(1, 2)):
    return [run_rank_cell(spec, n, True, SYNTH) for n in ranks]


@pytest.fixture(scope="module")
def scaling_doc():
    spec = ScalingSpec(name="metg_scaling.t", backend="shardmap-csp",
                       ranks=(1, 2))
    return scaling_artifact(spec, _cells(spec), smoke=True)


def test_scaling_artifact_schema(scaling_doc):
    doc = validate_artifact(scaling_doc)
    assert doc["kind"] == "metg_scaling"
    assert doc["scenario"]["ranks"] == [1, 2]
    r1, r2 = doc["cells"]
    assert r1["weak_efficiency"] == pytest.approx(1.0)
    assert 0.0 < r2["weak_efficiency"] <= 1.0
    assert r2["width"] == 2 * doc["scenario"]["width_per_rank"]
    # contour: every cell sweeps the same iteration grid
    assert ([p["iterations"] for p in r1["points"]]
            == [p["iterations"] for p in r2["points"]])


@pytest.mark.parametrize("mutate,match", [
    (lambda d: d["cells"].pop(), "cover"),
    (lambda d: d["cells"][0].__setitem__("ranks", 3), "cover"),
    (lambda d: d["scenario"].__setitem__("ranks", [2, 1]), "ascending"),
    (lambda d: d["cells"][1].__setitem__("width", 5), "width"),
    (lambda d: d["cells"][0].__setitem__("elapsed_s", float("nan")),
     "elapsed_s"),  # NaN fails _typed's finiteness guard
    (lambda d: d["cells"][0].__setitem__("elapsed_s", True),
     "elapsed_s"),  # bool <: int is rejected for numeric fields
    (lambda d: d["cells"][0]["points"][0].pop("weak_efficiency"),
     "weak_efficiency"),
    (lambda d: d.__setitem__("cells", []), "cover|cells"),
])
def test_scaling_artifact_rejects_corruption(scaling_doc, mutate, match):
    doc = copy.deepcopy(scaling_doc)
    mutate(doc)
    with pytest.raises(ValueError, match=match):
        validate_artifact(doc)


def test_compare_scaling_gate(scaling_doc):
    base = copy.deepcopy(scaling_doc)
    # identical -> ok, with the headline efficiency note
    res = compare_artifacts(base, copy.deepcopy(base))
    assert res.ok and res.note.startswith("eff@r2=")
    # per-rank elapsed regression trips
    cur = copy.deepcopy(base)
    cur["cells"][1]["elapsed_s"] *= 2.0
    for p in cur["cells"][1]["points"]:
        p["wall_time_s"] *= 2.0
    res = compare_artifacts(base, cur)
    assert not res.ok and any("ranks=2 elapsed" in r for r in res.regressions)
    # weak-efficiency drop trips even at equal elapsed threshold margins
    cur = copy.deepcopy(base)
    cur["cells"][1]["weak_efficiency"] *= 0.5
    res = compare_artifacts(base, cur)
    assert any("weak_efficiency" in r for r in res.regressions)
    # a shrunk rank list is an identity change (different experiment) —
    # caught before any numeric diff
    cur = copy.deepcopy(base)
    cur["cells"] = cur["cells"][:1]
    cur["scenario"]["ranks"] = [1]
    res = compare_artifacts(base, cur)
    assert any("scenario.ranks changed" in r for r in res.regressions)
    # a rank cell vanished with the scenario unchanged (a corrupt or
    # hand-edited doc slipping past identity) is a per-cell regression
    cur = copy.deepcopy(base)
    cur["cells"] = cur["cells"][:1]
    res = compare_artifacts(base, cur)
    assert any("ranks=2 missing" in r for r in res.regressions)
    # timer mismatch refuses to compare numbers
    cur = copy.deepcopy(base)
    cur["timer"] = "wallclock"
    res = compare_artifacts(base, cur)
    assert any("timer changed" in r for r in res.regressions)


# --------------------------------------------------- launcher integration
def test_run_scaling_subprocess_launcher(tmp_path):
    """End to end through real child processes: deterministic timer, so
    the subprocess cells equal in-process ``run_rank_cell`` exactly."""
    from repro.bench.scaling import _timer_payload, scaling_timer

    spec = ScalingSpec(name="metg_scaling.launch", backend="shardmap-csp",
                       ranks=(1, 2))
    result = run_scaling(spec, timer=SyntheticTimer(), smoke=True)
    payload = _timer_payload(scaling_timer(SyntheticTimer()))
    cells = [run_rank_cell(spec, n, True, payload) for n in (1, 2)]
    in_process = scaling_artifact(spec, cells, smoke=True)
    assert result.doc["cells"] == in_process["cells"]
    path = write_scaling_json(result, str(tmp_path))
    assert os.path.basename(path) == "BENCH_metg_scaling.launch.json"
    with open(path) as f:
        assert validate_artifact(json.load(f))["kind"] == "metg_scaling"


def test_bench_module_backends_filter(tmp_path, capsys):
    from benchmarks.run import main

    with pytest.raises(SystemExit) as exc:
        main(["--smoke", "--timer", "synthetic",
              "--only", "bench_metg_scaling",
              "--backends", "xla-scan",
              "--artifacts", str(tmp_path)])
    assert exc.value.code == 1
    assert "matches none" in capsys.readouterr().out


def test_scaling_backends_are_multirank_only():
    """The family must sweep exactly the backends whose CommPlan paths
    span ranks; a single-device backend in the list measures nothing."""
    assert set(SCALING_BACKENDS) == {
        "shardmap-csp", "shardmap-csp[comm=onesided]",
        "shardmap-pipeline", "shardmap-pipeline[comm=onesided]", "auto"}
