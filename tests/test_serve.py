"""Serving engine: continuous batching semantics + determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.layers import split_leaves
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
    return ServeEngine(cfg, params, batch_slots=2, max_len=64)


def test_lengths_and_completion(engine):
    r1 = engine.submit(np.array([1, 2, 3]), max_new_tokens=5)
    r2 = engine.submit(np.array([4, 5]), max_new_tokens=3)
    r3 = engine.submit(np.array([6]), max_new_tokens=4)  # second wave
    out = engine.run()
    assert set(out) == {r1, r2, r3}
    assert [len(out[r1]), len(out[r2]), len(out[r3])] == [5, 3, 4]


def test_batching_invariance(engine):
    """A request decodes the same alone or sharing a batch wave."""
    p = np.array([7, 8, 9])
    ra = engine.submit(p, max_new_tokens=4)
    alone = engine.run()[ra]
    rb = engine.submit(p, max_new_tokens=4)
    rc = engine.submit(p, max_new_tokens=4)
    out = engine.run()
    assert out[rb] == alone and out[rc] == alone


def test_encoder_only_rejected():
    cfg = reduced(get_config("hubert-xlarge"))
    params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
    with pytest.raises(AssertionError, match="encoder-only"):
        ServeEngine(cfg, params)
