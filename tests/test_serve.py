"""Serving engine: continuous-batching semantics, chunked-decode
conformance, slot lifecycle, and the serve_load bench family."""
import dataclasses
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.cache import init_caches, reset_slot
from repro.models.layers import split_leaves
from repro.serve.engine import ServeEngine, prefill, serve_step


def _build(name):
    cfg = reduced(get_config(name))
    params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
    return cfg, params


@pytest.fixture(scope="module")
def qwen():
    return _build("qwen1.5-0.5b")       # full attention, stacked scan


@pytest.fixture(scope="module")
def gemma():
    return _build("recurrentgemma-2b")  # ring + rglru, heterogeneous list


@pytest.fixture(scope="module")
def mamba():
    return _build("mamba2-2.7b")        # ssm, stacked scan


@pytest.fixture(scope="module")
def engine(qwen):
    cfg, params = qwen
    return ServeEngine(cfg, params, batch_slots=2, max_len=64)


REQS = [([1, 2, 3], 7), ([4, 5], 3), ([6], 5), ([7, 8, 9, 1], 4)]


def _drain(cfg, params, mode, reqs=REQS, chunk_size=4, eos=None):
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64,
                      chunk_size=chunk_size, decode_mode=mode)
    rids = [eng.submit(np.array(p), max_new_tokens=m, eos_id=eos)
            for p, m in reqs]
    out = eng.run()
    return [out[r] for r in rids], eng.stats


# ------------------------------------------------------------ base semantics
def test_lengths_and_completion(engine):
    r1 = engine.submit(np.array([1, 2, 3]), max_new_tokens=5)
    r2 = engine.submit(np.array([4, 5]), max_new_tokens=3)
    r3 = engine.submit(np.array([6]), max_new_tokens=4)  # second wave
    out = engine.run()
    assert set(out) == {r1, r2, r3}
    assert [len(out[r1]), len(out[r2]), len(out[r3])] == [5, 3, 4]


def test_batching_invariance(engine):
    """A request decodes the same alone or sharing a batch wave."""
    p = np.array([7, 8, 9])
    ra = engine.submit(p, max_new_tokens=4)
    alone = engine.run()[ra]
    rb = engine.submit(p, max_new_tokens=4)
    rc = engine.submit(p, max_new_tokens=4)
    out = engine.run()
    assert out[rb] == alone and out[rc] == alone


def test_encoder_only_rejected():
    cfg = reduced(get_config("hubert-xlarge"))
    params, _ = split_leaves(M.init_model(jax.random.PRNGKey(0), cfg))
    with pytest.raises(AssertionError, match="encoder-only"):
        ServeEngine(cfg, params)


def test_submit_validation(engine):
    with pytest.raises(ValueError, match="exceeds max_len"):
        engine.submit(np.arange(60), max_new_tokens=16)
    with pytest.raises(ValueError, match="non-empty"):
        engine.submit(np.array([], np.int32))
    with pytest.raises(ValueError, match="decode_mode"):
        ServeEngine(engine.cfg, engine.params, decode_mode="turbo")


# ------------------------------------------- chunked-decode conformance suite
@pytest.mark.parametrize("model", ["qwen", "gemma", "mamba"])
def test_chunked_matches_host_mixed_budgets(model, request):
    """On-device chunked decode is bit-exact vs the per-token host loop
    for mixed max_new_tokens — including requests admitted mid-decode
    (4 requests into 2 slots)."""
    cfg, params = request.getfixturevalue(model)
    chunked, s_chunk = _drain(cfg, params, "chunked")
    host, s_host = _drain(cfg, params, "host")
    assert chunked == host
    # same work, radically different sync counts
    assert s_chunk["tokens_generated"] == s_host["tokens_generated"]
    assert s_chunk["host_syncs"] < s_host["host_syncs"]


@pytest.mark.parametrize("model", ["qwen", "gemma", "mamba"])
def test_admission_matches_alone(model, request):
    """Mid-decode admission yields exactly the tokens each request
    produces running alone on a fresh engine (batch-row independence +
    unpadded B=1 prefill)."""
    cfg, params = request.getfixturevalue(model)
    together, _ = _drain(cfg, params, "chunked")
    for (p, m), got in zip(REQS, together):
        alone, _ = _drain(cfg, params, "chunked", reqs=[(p, m)])
        assert got == alone[0], (p, m)


@pytest.mark.parametrize("model", ["qwen", "gemma", "mamba"])
def test_slot_reuse_leak_free(model, request):
    """A slot recycled through noisy prior requests serves a later
    request identically to a fresh engine (reset_slot + write_prompt
    leave no residue, all cache kinds)."""
    cfg, params = request.getfixturevalue(model)
    target = ([9, 1, 9], 6)
    fresh, _ = _drain(cfg, params, "chunked", reqs=[target])
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64, chunk_size=4)
    for p, m in REQS:  # churn every slot through several lifecycles
        eng.submit(np.array(p), max_new_tokens=m)
    eng.run()
    rid = eng.submit(np.array(target[0]), max_new_tokens=target[1])
    assert eng.run()[rid] == fresh[0]


def test_reset_slot_zeroes_one_slot():
    """Cache-level: reset_slot zeroes exactly the reset slot's state and
    cursor for every cache kind, list and stacked layouts."""
    for name in ("qwen1.5-0.5b", "recurrentgemma-2b", "mamba2-2.7b"):
        cfg = reduced(get_config(name))
        # max_len 64 > local_window 32 so recurrentgemma gets ring buffers
        caches = init_caches(cfg, 2, 64, per_slot_pos=True)
        dirty = [jax.tree.map(jnp.ones_like, c) for c in caches]
        wiped = reset_slot(dirty, 0)
        for c in wiped:
            for leaf in jax.tree.leaves(c):
                assert bool((leaf[0] == 0).all()), (name, c.kind)
                assert bool((leaf[1] == 1).all()), (name, c.kind)


def test_host_sync_bound_structural(qwen):
    """Chunked decode syncs at most ceil(tokens/chunk) + 1 times per
    request; the host loop pays one sync per token."""
    cfg, params = qwen
    tokens, chunk = 13, 4
    out, stats = _drain(cfg, params, "chunked", reqs=[([1, 2], tokens)],
                        chunk_size=chunk)
    assert len(out[0]) == tokens
    assert stats["host_syncs"] <= math.ceil(tokens / chunk) + 1
    assert stats["chunk_launches"] == math.ceil((tokens - 1) / chunk)
    _, stats_h = _drain(cfg, params, "host", reqs=[([1, 2], tokens)])
    assert stats_h["host_syncs"] == tokens  # prefill + (tokens-1) steps


def test_eos_early_stop_both_modes(qwen):
    """eos_id truncates at the first occurrence, identically in both
    decode paths, and the eos token itself is emitted."""
    cfg, params = qwen
    full, _ = _drain(cfg, params, "chunked", reqs=[([1, 2, 3], 7)])
    seq = full[0]
    # pick an eos that first appears strictly inside the sequence
    k, eos = next((i, t) for i, t in enumerate(seq)
                  if 0 < i < len(seq) - 1 and t not in seq[:i])
    for mode in ("chunked", "host"):
        got, _ = _drain(cfg, params, mode, reqs=[([1, 2, 3], 7)], eos=eos)
        assert got[0] == seq[:k + 1], mode


# --------------------------------------------------- left-padding regression
@pytest.mark.parametrize("window", [None, 8])
def test_padded_prefill_matches_unpadded(qwen, window):
    """A left-padded wave prefill (+3 decode steps) matches per-request
    unpadded prefills bit-exactly: pad rows are masked out of the KV
    cache via per-slot start offsets (full and ring cache layouts)."""
    cfg, params = qwen
    if window is not None:
        cfg = dataclasses.replace(cfg, window=window)  # force ring buffers
    prompts = [np.array([1, 2, 3, 4, 5]), np.array([7, 8]),
               np.array([9, 9, 9])]
    plen = max(len(p) for p in prompts)
    toks = np.zeros((3, plen), np.int32)
    pad = np.array([plen - len(p) for p in prompts], np.int32)
    for i, p in enumerate(prompts):
        toks[i, plen - len(p):] = p
    caches = init_caches(cfg, 3, 32)
    nxt, caches = prefill(params, jnp.asarray(toks), caches, pos=0,
                          cfg=cfg, pad=pad)
    wave = [[int(nxt[i, 0])] for i in range(3)]
    cur, pos = nxt, plen
    for _ in range(3):
        cur, caches = serve_step(params, cur, caches,
                                 jnp.asarray(pos - pad), cfg=cfg)
        pos += 1
        for i in range(3):
            wave[i].append(int(cur[i, 0]))
    for i, p in enumerate(prompts):
        c1 = init_caches(cfg, 1, 32)
        n1, c1 = prefill(params, jnp.asarray(p)[None, :], c1, pos=0, cfg=cfg)
        ref, cur1, pos1 = [int(n1[0, 0])], n1, len(p)
        for _ in range(3):
            cur1, c1 = serve_step(params, cur1, c1, jnp.int32(pos1), cfg=cfg)
            pos1 += 1
            ref.append(int(cur1[0, 0]))
        assert wave[i] == ref, (window, i)


def test_per_slot_cursors_reject_multi_token(qwen):
    """Per-slot cache cursors are decode-only: a multi-token forward
    must fail loudly, not corrupt slots (prefill goes through B=1 +
    write_prompt)."""
    cfg, params = qwen
    caches = init_caches(cfg, 2, 32, per_slot_pos=True)
    with pytest.raises(ValueError, match="per-slot"):
        M.forward(params, cfg, tokens=jnp.ones((2, 3), jnp.int32),
                  caches=caches, pos=0)


# ------------------------------------------------------- serve_load family
def _sim_spec(mode, rate=2000.0, **kw):
    from repro.bench.serve import ServeLoadSpec

    kw.setdefault("num_requests", 32)
    kw.setdefault("batch_slots", 4)
    kw.setdefault("out_tokens", (4, 24))
    return ServeLoadSpec(name=f"serve_load.{mode}.rate{int(rate)}",
                         mode=mode, rate_rps=rate, chunk_size=8, max_len=64,
                         prompt_len=(4, 8), seed=0, **kw)


def test_serve_trace_deterministic():
    from repro.bench.serve import synth_trace

    spec = _sim_spec("chunked")
    assert synth_trace(spec) == synth_trace(spec)
    other = dataclasses.replace(spec, seed=1)
    assert synth_trace(other) != synth_trace(spec)


def test_serve_sim_deterministic_and_chunked_wins():
    """The discrete-event model is bit-deterministic, and the chunked
    engine strictly beats the per-token host loop on decode throughput
    and sync count at every traced load point."""
    from benchmarks.bench_serve_load import RATES
    from repro.bench.serve import simulate_serve_load

    for rate in RATES:
        host = simulate_serve_load(_sim_spec("host", rate)).metrics
        chunked = simulate_serve_load(_sim_spec("chunked", rate)).metrics
        again = simulate_serve_load(_sim_spec("chunked", rate)).metrics
        assert chunked == again
        assert chunked["throughput_tok_s"] > host["throughput_tok_s"], rate
        assert chunked["tpot_s"]["p50"] < host["tpot_s"]["p50"], rate
        assert (chunked["host_syncs_per_token"]
                < host["host_syncs_per_token"]), rate
        # the tentpole's sync arithmetic, exactly: one sync per prefill
        # plus one per chunk launch / per decode step
        assert chunked["host_syncs"] == (chunked["prefills"]
                                         + chunked["chunk_launches"])
        assert host["host_syncs"] == host["prefills"] + host["decode_steps"]


def test_serve_sim_counters_match_real_engine(qwen):
    """The simulator replays the engine's actual schedule: with every
    arrival effectively immediate, its prefill/step/launch/sync counters
    equal the real engine's stats on the same trace."""
    from repro.bench.serve import simulate_serve_load, synth_trace

    cfg, params = qwen
    spec = _sim_spec("chunked", rate=1e9, num_requests=6,
                     batch_slots=2, out_tokens=(2, 9))
    sim = simulate_serve_load(spec).metrics
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=spec.max_len,
                      chunk_size=spec.chunk_size)
    rng = np.random.default_rng(0)
    for r in synth_trace(spec):
        eng.submit(rng.integers(1, cfg.vocab_size, size=r.prompt_len)
                   .astype(np.int32), max_new_tokens=r.out_tokens)
    eng.run()
    for k in ("prefills", "decode_steps", "chunk_launches", "host_syncs",
              "tokens_generated"):
        assert sim[k] == eng.stats[k], k


def test_serve_artifact_roundtrip_and_schema(tmp_path):
    from repro.bench import read_bench_json, validate_artifact
    from repro.bench.serve import (serve_artifact, simulate_serve_load,
                                   write_serve_json)

    res = simulate_serve_load(_sim_spec("chunked"))
    path = write_serve_json(res, str(tmp_path))
    doc = read_bench_json(path)
    assert doc["kind"] == "serve_load" and doc["timer"] == "synthetic"
    assert doc["scenario"]["mode"] == "chunked"
    bad = serve_artifact(res)
    bad["metrics"]["ttft_s"]["p50"] = "fast"
    with pytest.raises(ValueError, match="ttft_s.p50"):
        validate_artifact(bad)
    bad2 = serve_artifact(res)
    bad2["kind"] = "not_a_sweep"
    with pytest.raises(ValueError, match="unknown kind"):
        validate_artifact(bad2)
    bad3 = serve_artifact(res)
    del bad3["metrics"]["throughput_tok_s"]
    with pytest.raises(ValueError, match="throughput_tok_s"):
        validate_artifact(bad3)


def test_serve_compare_gates():
    """serve_load regression gate: slower throughput or fatter latency
    tails fail; identity/timer/kind mismatches refuse to compare."""
    import copy

    from repro.bench import compare_artifacts
    from repro.bench.serve import serve_artifact, simulate_serve_load

    base = serve_artifact(simulate_serve_load(_sim_spec("chunked")))
    assert compare_artifacts(base, copy.deepcopy(base)).ok

    slow = copy.deepcopy(base)
    slow["metrics"]["throughput_tok_s"] *= 0.5
    res = compare_artifacts(base, slow)
    assert not res.ok and any("throughput" in r for r in res.regressions)

    tails = copy.deepcopy(base)
    tails["metrics"]["ttft_s"]["p99"] *= 10
    res = compare_artifacts(base, tails)
    assert not res.ok and any("ttft_s.p99" in r for r in res.regressions)

    other_timer = copy.deepcopy(base)
    other_timer["timer"] = "wallclock"
    assert any("timer changed" in r
               for r in compare_artifacts(base, other_timer).regressions)

    other_mode = copy.deepcopy(base)
    other_mode["scenario"]["mode"] = "host"
    assert any("scenario.mode" in r
               for r in compare_artifacts(base, other_mode).regressions)

    other_kind = copy.deepcopy(base)
    other_kind["kind"] = "metg_sweep"
    assert any("kind changed" in r
               for r in compare_artifacts(base, other_kind).regressions)


def test_committed_serve_baselines_show_the_tentpole_claim():
    """The committed BENCH_serve_load.*.json snapshot itself must show
    the chunked engine strictly outperforming the per-token host loop on
    decode throughput (and sync count) at EVERY traced load point."""
    from benchmarks.bench_serve_load import RATES
    from repro.bench import read_bench_json

    basedir = os.path.join(os.path.dirname(__file__), "..",
                           "benchmarks", "baselines")

    def doc(mode, rate):
        return read_bench_json(os.path.join(
            basedir, f"BENCH_serve_load.{mode}.rate{int(rate)}.json"))

    for rate in RATES:
        host, chunked = doc("host", rate), doc("chunked", rate)
        assert host["timer"] == chunked["timer"] == "synthetic"
        hm, cm = host["metrics"], chunked["metrics"]
        assert cm["throughput_tok_s"] > hm["throughput_tok_s"], rate
        assert cm["host_syncs_per_token"] < hm["host_syncs_per_token"], rate
        assert cm["tpot_s"]["p50"] < hm["tpot_s"]["p50"], rate
