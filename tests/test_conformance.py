"""Backend-matrix conformance: every pattern on every registered backend.

The executable form of the paper's claim that "every benchmark
constructed with Task Bench runs on every Task Bench implementation":
the full cross-product is parametrized (one cell per test) and each
cell's checksum slots must match the numpy oracle bit-exactly.  New
backends join the matrix just by registering — the pipeline backend
passes unmodified.
"""
import numpy as np
import pytest

from repro.backends import backend_names, get_backend
from repro.core import (check_outputs, execute_reference, make_graph,
                        pattern_names, replicate)

PATTERN_KW = {"nearest": {"radix": 3}, "spread": {"radix": 3}}


def conformance_graph(pattern):
    return make_graph(width=6, height=8, pattern=pattern, iterations=3,
                      **PATTERN_KW.get(pattern, {}))


@pytest.fixture(scope="module")
def oracle():
    cache = {}

    def get(graph):
        key = repr(graph)
        if key not in cache:
            cache[key] = execute_reference(graph)
        return cache[key]

    return get


@pytest.mark.parametrize("pattern", pattern_names())
@pytest.mark.parametrize("backend", backend_names())
def test_backend_pattern_conformance(backend, pattern, oracle):
    g = conformance_graph(pattern)
    out = get_backend(backend).run([g])[0]
    # check_outputs: slots 0..3 (coords + checksums) bit-exact, kernel
    # slots within reduction-order tolerance
    check_outputs(g, out, expected=oracle(g))


def test_pipeline_backend_registered():
    assert "shardmap-pipeline" in backend_names()
    be = get_backend("shardmap-pipeline")
    assert be.axis == "stage"
    assert be.prefer_ring


# stencil rides the halo/ring paths, spread the allgather path — together
# they cover every comm mode the concurrent programs can take
MULTI_GRAPH_PATTERNS = ("stencil", "spread")


@pytest.mark.parametrize("ngraphs", [2, 3])
@pytest.mark.parametrize("backend", backend_names())
def test_run_many_matches_single_graph(backend, ngraphs, oracle):
    """Concurrent replicated graphs (paper Fig 9d) through ``run_many``
    produce the same bit-exact checksum slots as running each graph alone,
    for every registered backend."""
    be = get_backend(backend)
    for pattern in MULTI_GRAPH_PATTERNS:
        g = conformance_graph(pattern)
        alone = np.asarray(be.run([g])[0])
        outs = be.run_many(replicate(g, ngraphs))
        assert len(outs) == ngraphs
        for out in outs:
            check_outputs(g, out, expected=oracle(g))
            assert (np.asarray(out)[:, :4] == alone[:, :4]).all(), (
                backend, pattern, ngraphs)


@pytest.mark.parametrize("backend", backend_names())
def test_run_many_heterogeneous_patterns(backend, oracle):
    """Mixed-pattern concurrent graphs: the stacked/interleaved programs
    must keep per-graph dependence data separate (different comm modes in
    one combined SPMD program)."""
    graphs = [conformance_graph(p) for p in ("stencil", "sweep", "fft")]
    outs = get_backend(backend).run_many(graphs)
    assert len(outs) == len(graphs)
    for g, out in zip(graphs, outs):
        check_outputs(g, out, expected=oracle(g))


@pytest.mark.parametrize("backend", backend_names())
def test_run_many_mixed_shapes_falls_back(backend, oracle):
    """Graphs that cannot share one program (different shapes) still run
    correctly through ``run_many`` via the sequential fallback."""
    graphs = [
        conformance_graph("stencil"),
        make_graph(width=4, height=5, pattern="sweep", iterations=2),
    ]
    outs = get_backend(backend).run_many(graphs)
    for g, out in zip(graphs, outs):
        check_outputs(g, out, expected=oracle(g))
