"""Backend-matrix conformance: every pattern on every registered backend.

The executable form of the paper's claim that "every benchmark
constructed with Task Bench runs on every Task Bench implementation":
the full cross-product is parametrized (one cell per test) and each
cell's checksum slots must match the numpy oracle bit-exactly.  New
backends join the matrix just by registering — the pipeline backend
passes unmodified.
"""
import numpy as np
import pytest

from repro.backends import backend_names, get_backend
from repro.core import (check_outputs, execute_reference, make_graph,
                        pattern_names, replicate)

# the SPMD backends also accept a forced comm mode; "a2a" (the
# MPI_Alltoallv analogue added for MoE dispatch planning) joins the
# conformance matrix through test_forced_a2a_conformance below

PATTERN_KW = {"nearest": {"radix": 3}, "spread": {"radix": 3}}


def conformance_graph(pattern):
    return make_graph(width=6, height=8, pattern=pattern, iterations=3,
                      **PATTERN_KW.get(pattern, {}))


@pytest.fixture(scope="module")
def oracle():
    cache = {}

    def get(graph):
        key = repr(graph)
        if key not in cache:
            cache[key] = execute_reference(graph)
        return cache[key]

    return get


@pytest.mark.parametrize("pattern", pattern_names())
@pytest.mark.parametrize("backend", backend_names())
def test_backend_pattern_conformance(backend, pattern, oracle):
    g = conformance_graph(pattern)
    out = get_backend(backend).run([g])[0]
    # check_outputs: slots 0..3 (coords + checksums) bit-exact, kernel
    # slots within reduction-order tolerance
    check_outputs(g, out, expected=oracle(g))


@pytest.mark.parametrize("pattern", pattern_names())
def test_forced_a2a_conformance(pattern, oracle):
    """Every pattern through the CSP backend with the per-pair a2a
    exchange forced (the static CommPlan mode backing MoE dispatch)."""
    g = conformance_graph(pattern)
    be = get_backend("shardmap-csp", comm="a2a")
    assert be.plan(g).mode == "a2a"
    check_outputs(g, be.run([g])[0], expected=oracle(g))


def test_pipeline_backend_registered():
    assert "shardmap-pipeline" in backend_names()
    be = get_backend("shardmap-pipeline")
    assert be.axis == "stage"
    assert be.prefer_ring


# stencil rides the halo/ring paths, spread the allgather path — together
# they cover every comm mode the concurrent programs can take
MULTI_GRAPH_PATTERNS = ("stencil", "spread")


@pytest.mark.parametrize("ngraphs", [2, 3])
@pytest.mark.parametrize("backend", backend_names())
def test_run_many_matches_single_graph(backend, ngraphs, oracle):
    """Concurrent replicated graphs (paper Fig 9d) through ``run_many``
    produce the same bit-exact checksum slots as running each graph alone,
    for every registered backend."""
    be = get_backend(backend)
    for pattern in MULTI_GRAPH_PATTERNS:
        g = conformance_graph(pattern)
        alone = np.asarray(be.run([g])[0])
        outs = be.run_many(replicate(g, ngraphs))
        assert len(outs) == ngraphs
        for out in outs:
            check_outputs(g, out, expected=oracle(g))
            assert (np.asarray(out)[:, :4] == alone[:, :4]).all(), (
                backend, pattern, ngraphs)


@pytest.mark.parametrize("backend", backend_names())
def test_run_many_single_graph_degenerate_stack(backend, oracle):
    """ngraphs=1 through ``run_many`` — the degenerate stack.  The stacked
    (graph-dim) programs, interleaved wavefronts, and combined shard_map
    scan must all collapse correctly to one graph, bit-exact vs ``run``."""
    be = get_backend(backend)
    for pattern in MULTI_GRAPH_PATTERNS:
        g = conformance_graph(pattern)
        alone = np.asarray(be.run([g])[0])
        outs = be.run_many(replicate(g, 1))
        assert len(outs) == 1
        check_outputs(g, outs[0], expected=oracle(g))
        assert (np.asarray(outs[0])[:, :4] == alone[:, :4]).all(), (
            backend, pattern)


def imbalanced_graph(pattern="stencil"):
    # imbalance scales each task's iteration count by U[1-imb, 1],
    # deterministic in (t, i, seed) — the per-task work is heterogeneous
    return make_graph(width=6, height=8, pattern=pattern, iterations=6,
                      imbalance=0.7, **PATTERN_KW.get(pattern, {}))


# the study modes (paper §V-F/G mechanisms): work-stealing dispatch and
# double-buffered communication.  Spec strings go through
# get_backend("name[key=value]"), the same form ScenarioSpec.backend
# carries, so these cells also pin the spec-string path.  On the CI
# multi-rank step (JAX_NUM_CPU_DEVICES=8) the 6-wide graphs are ragged
# over 8 ranks.
STUDY_MODE_BACKENDS = (
    "host-dynamic[schedule=steal]",
    "shardmap-csp[comm_overlap=True]",
    "shardmap-pipeline[comm_overlap=True]",
    "shardmap-csp[comm=onesided]",
    "shardmap-csp[comm=onesided,comm_overlap=True]",
    "shardmap-pipeline[comm=onesided]",
)


@pytest.mark.parametrize("pattern", pattern_names())
@pytest.mark.parametrize("backend", STUDY_MODE_BACKENDS)
def test_study_mode_conformance(backend, pattern, oracle):
    """schedule="steal" and comm_overlap=True must be bit-exact vs the
    oracle for every pattern (their mechanisms reorder dispatch / rotate
    the exchange, never the values)."""
    g = conformance_graph(pattern)
    check_outputs(g, get_backend(backend).run([g])[0], expected=oracle(g))


@pytest.mark.parametrize("backend", STUDY_MODE_BACKENDS)
def test_study_mode_imbalanced_and_ragged(backend):
    """The study modes under the conditions they exist for: imbalanced
    kernels (heterogeneous per-task durations) and ragged widths (10
    columns pad over 4/8 ranks; steal wavefronts wider than the worker
    pool)."""
    for g in (
        imbalanced_graph(),
        make_graph(width=10, height=6, pattern="stencil", iterations=5,
                   imbalance=1.5),
        make_graph(width=3, height=5, pattern="sweep", iterations=4,
                   imbalance=2.0),
    ):
        check_outputs(g, get_backend(backend).run([g])[0],
                      expected=execute_reference(g))


@pytest.mark.parametrize("backend", STUDY_MODE_BACKENDS)
def test_study_mode_run_many(backend, oracle):
    """The concurrent programs in study mode: the combined shard_map scan
    must double-buffer every graph's exchange, and stealing wavefronts
    must interleave across graphs, all bit-exact vs the single run."""
    be = get_backend(backend)
    for pattern in MULTI_GRAPH_PATTERNS:
        g = conformance_graph(pattern)
        alone = np.asarray(be.run([g])[0])
        outs = be.run_many(replicate(g, 2))
        assert len(outs) == 2
        for out in outs:
            check_outputs(g, out, expected=oracle(g))
            assert (np.asarray(out)[:, :4] == alone[:, :4]).all(), (
                backend, pattern)


def test_host_dynamic_run_many_imbalanced_kernel():
    """The host backend's interleaved wavefronts under an imbalanced
    kernel: per-task durations differ, so the dispatch interleaving must
    not mix up which iteration count belongs to which task — bit-exact vs
    the single-graph run and the oracle."""
    be = get_backend("host-dynamic")
    g = imbalanced_graph()
    expected = execute_reference(g)
    alone = np.asarray(be.run([g])[0])
    check_outputs(g, alone, expected=expected)
    outs = be.run_many(replicate(g, 3))
    assert len(outs) == 3
    for out in outs:
        check_outputs(g, out, expected=expected)
        assert (np.asarray(out)[:, :4] == alone[:, :4]).all()


@pytest.mark.parametrize("backend", backend_names())
def test_run_many_heterogeneous_patterns(backend, oracle):
    """Mixed-pattern concurrent graphs: the stacked/interleaved programs
    must keep per-graph dependence data separate (different comm modes in
    one combined SPMD program)."""
    graphs = [conformance_graph(p) for p in ("stencil", "sweep", "fft")]
    outs = get_backend(backend).run_many(graphs)
    assert len(outs) == len(graphs)
    for g, out in zip(graphs, outs):
        check_outputs(g, out, expected=oracle(g))


@pytest.mark.parametrize("backend", backend_names())
def test_run_many_mixed_shapes_falls_back(backend, oracle):
    """Graphs that cannot share one program (different shapes) still run
    correctly through ``run_many`` via the sequential fallback."""
    graphs = [
        conformance_graph("stencil"),
        make_graph(width=4, height=5, pattern="sweep", iterations=2),
    ]
    outs = get_backend(backend).run_many(graphs)
    for g, out in zip(graphs, outs):
        check_outputs(g, out, expected=oracle(g))
