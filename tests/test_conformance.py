"""Backend-matrix conformance: every pattern on every registered backend.

The executable form of the paper's claim that "every benchmark
constructed with Task Bench runs on every Task Bench implementation":
the full cross-product is parametrized (one cell per test) and each
cell's checksum slots must match the numpy oracle bit-exactly.  New
backends join the matrix just by registering — the pipeline backend
passes unmodified.
"""
import pytest

from repro.backends import backend_names, get_backend
from repro.core import (check_outputs, execute_reference, make_graph,
                        pattern_names)

PATTERN_KW = {"nearest": {"radix": 3}, "spread": {"radix": 3}}


def conformance_graph(pattern):
    return make_graph(width=6, height=8, pattern=pattern, iterations=3,
                      **PATTERN_KW.get(pattern, {}))


@pytest.fixture(scope="module")
def oracle():
    cache = {}

    def get(graph):
        key = repr(graph)
        if key not in cache:
            cache[key] = execute_reference(graph)
        return cache[key]

    return get


@pytest.mark.parametrize("pattern", pattern_names())
@pytest.mark.parametrize("backend", backend_names())
def test_backend_pattern_conformance(backend, pattern, oracle):
    g = conformance_graph(pattern)
    out = get_backend(backend).run([g])[0]
    # check_outputs: slots 0..3 (coords + checksums) bit-exact, kernel
    # slots within reduction-order tolerance
    check_outputs(g, out, expected=oracle(g))


def test_pipeline_backend_registered():
    assert "shardmap-pipeline" in backend_names()
    be = get_backend("shardmap-pipeline")
    assert be.axis == "stage"
    assert be.prefer_ring
