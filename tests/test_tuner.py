"""Self-tuning backend planner: cutouts, mode space, table, auto dispatch.

Everything here runs on the deterministic fake clock (``SyntheticTimer``)
or pure table lookups, so the planner tests are exactly reproducible —
including the committed-table assertions that pin the paper's tentpole
claim (no single backend wins everywhere: the fused megakernel owns the
dispatch-bound cells, a one-sided SPMD spec owns the payload-bound ones).
"""
import json
import math
import os

import numpy as np
import pytest

from repro.backends import backend_names, get_backend
from repro.bench import (ScenarioSpec, SweepControls, SyntheticTimer,
                         TuningKey, TuningTable, auto_resolve,
                         build_tuning_table, diff_tuning_tables,
                         enumerate_mode_space, granularity_bucket,
                         graphs_cutout, load_tuning_table, payload_bucket,
                         read_tuning_json, spec_cutout,
                         validate_tuning_table, write_tuning_json)
from repro.bench.tuner import (DEFAULT_FALLBACK, backend_mode_specs,
                               default_table_path, key_slug, tuning_corpus,
                               tuning_table_path)
from repro.core import check_outputs, execute_reference, make_graph


# ------------------------------------------------------------------ buckets
def test_granularity_buckets_cover_the_axis():
    assert granularity_bucket(0) == "fine"
    assert granularity_bucket(15.9) == "fine"
    assert granularity_bucket(16) == "medium"
    assert granularity_bucket(255.9) == "medium"
    assert granularity_bucket(256) == "coarse"
    assert granularity_bucket(1e9) == "coarse"


def test_payload_buckets_cover_the_axis():
    assert payload_bucket(0) == "small"
    assert payload_bucket(1023) == "small"
    assert payload_bucket(1024) == "medium"
    assert payload_bucket(32767) == "medium"
    assert payload_bucket(32768) == "large"


def test_buckets_reject_garbage():
    with pytest.raises(ValueError):
        granularity_bucket(-1)
    with pytest.raises(ValueError):
        granularity_bucket(float("nan"))
    with pytest.raises(ValueError):
        granularity_bucket(float("inf"))
    with pytest.raises(ValueError):
        payload_bucket(-1)


def test_tuning_key_validates_eagerly():
    with pytest.raises(ValueError):
        TuningKey("stencil", "ultrafine", "small")
    with pytest.raises(ValueError):
        TuningKey("stencil", "fine", "huge")
    with pytest.raises(ValueError):
        TuningKey("", "fine", "small")
    with pytest.raises(ValueError):
        TuningKey("stencil", "fine", "small", ndev=0)
    assert key_slug(TuningKey("stencil", "fine", "small")) == \
        "stencil.fine.small.d1.g1"


# ------------------------------------------------------------------ cutouts
def test_graphs_cutout_reduces_a_workload_to_its_key():
    g = make_graph(width=4, height=6, pattern="stencil", iterations=64,
                   output_bytes=4096)
    assert graphs_cutout([g]) == TuningKey("stencil", "medium", "medium")
    assert graphs_cutout([g, g], ndev=8) == TuningKey(
        "stencil", "medium", "medium", ndev=8, ngraphs=2)
    with pytest.raises(ValueError):
        graphs_cutout([])


def test_spec_cutout_needs_a_single_point_sweep():
    spec = ScenarioSpec(name="cut", pattern="nearest", width=4, height=6,
                        output_bytes=16,
                        sweep=SweepControls(schedule=(64,)))
    assert spec_cutout(spec) == TuningKey("nearest", "medium", "small")
    multi = ScenarioSpec(name="cut2", pattern="nearest", width=4, height=6,
                         sweep=SweepControls(iterations_hi=64, n_points=3))
    with pytest.raises(ValueError, match="single-point"):
        spec_cutout(multi)


# --------------------------------------------------------------- mode space
def test_mode_space_prunes_illegal_combos():
    """Candidates come from each constructor's signature, with combos the
    constructor vetoes dropped — no hand-maintained legality table."""
    specs = enumerate_mode_space()
    assert "auto" not in {s.split("[")[0] for s in specs}
    # the megakernel accepts one-sided (its native in-kernel signaling)
    # but not the rendezvous comm modes
    pallas = backend_mode_specs("pallas-fused")
    assert pallas == ["pallas-fused", "pallas-fused[comm=onesided]"]
    # host dispatch sweeps its scheduling policy, nothing else
    assert backend_mode_specs("host-dynamic") == [
        "host-dynamic", "host-dynamic[schedule=steal]"]
    # SPMD backends sweep comm x overlap (schedule is not a ctor option)
    assert "shardmap-csp[comm=onesided,comm_overlap=True]" in specs
    # every candidate is canonical and instantiable
    from repro.backends.base import canonical_backend_spec

    for s in specs:
        assert canonical_backend_spec(s) == s
        get_backend(s)


def test_tuning_corpus_smoke_is_a_subset_of_the_full_grid():
    full = {c.key for c in tuning_corpus(smoke=False)}
    smoke = {c.key for c in tuning_corpus(smoke=True)}
    assert smoke < full


# ------------------------------------------------------- table build + files
def test_build_tuning_table_round_trips(tmp_path):
    doc = build_tuning_table(timer=SyntheticTimer(), smoke=True)
    path = write_tuning_json(doc, str(tmp_path))
    assert os.path.basename(path) == "TUNE_default.json"
    back = read_tuning_json(path)
    assert back == json.loads(json.dumps(doc))
    table = TuningTable(back, path=path)
    assert table.timer == "synthetic"
    # winner margins are measured against the next *distinct* candidate
    for e in back["entries"]:
        times = sorted(t for _, t in e["candidates"])
        assert e["elapsed_s"] == times[0]
        slower = [t for t in times if t > times[0]]
        if slower:
            assert e["margin"] == pytest.approx(
                (min(slower) - times[0]) / times[0])


def test_tuning_table_rejects_corruption(tmp_path):
    doc = json.loads(json.dumps(build_tuning_table(smoke=True)))
    validate_tuning_table(doc)

    def broken(mutate):
        bad = json.loads(json.dumps(doc))
        mutate(bad)
        with pytest.raises(ValueError):
            validate_tuning_table(bad)

    broken(lambda d: d.update(schema=99))
    broken(lambda d: d.update(kind="bench"))
    broken(lambda d: d.update(timer=""))
    broken(lambda d: d.update(entries=[]))
    broken(lambda d: d["entries"][0]["key"].update(flavor="spicy"))
    broken(lambda d: d["entries"][0]["key"].update(granularity="ultrafine"))
    broken(lambda d: d["entries"][0].update(margin=float("nan")))
    broken(lambda d: d["entries"][0].update(margin=-0.1))
    broken(lambda d: d["entries"][0].update(margin=True))  # bool != number
    broken(lambda d: d["entries"][0].update(elapsed_s=0.0))
    broken(lambda d: d["entries"][0].update(winner="no-such-backend spec"))
    broken(lambda d: d["entries"][0].update(winner="xla-scan[b=1,a=2]"))
    broken(lambda d: d["entries"][0].update(
        winner="host-dynamic[workers=99]"))  # parseable, not a candidate
    broken(lambda d: d["entries"].append(d["entries"][0]))  # duplicate key
    # truncated/garbage files raise ValueError naming the path
    stub = tmp_path / "TUNE_default.json"
    stub.write_text('{"schema": 1, "kind": "tuning_')
    with pytest.raises(ValueError, match="TUNE_default.json"):
        read_tuning_json(str(stub))


def test_load_tuning_table_explicit_path_must_exist(tmp_path):
    with pytest.raises(ValueError, match="not found"):
        load_tuning_table(str(tmp_path / "TUNE_nope.json"))


# --------------------------------------------------------------- resolution
def _mini_table():
    """A hand-built two-entry table exercising every resolution tier."""
    mk = lambda key, winner: {
        "key": key.to_dict(), "family": "metg", "winner": winner,
        "elapsed_s": 1e-3, "margin": 0.5,
        "candidates": [[winner, 1e-3], ["xla-scan", 2e-3]]}
    return TuningTable({
        "schema": 1, "kind": "tuning_table", "timer": "synthetic",
        "timer_config": {},
        "entries": [
            mk(TuningKey("stencil", "fine", "small"), "pallas-fused"),
            mk(TuningKey("stencil", "coarse", "large", ngraphs=4),
               "shardmap-csp[comm=onesided]"),
        ]})


def test_resolution_tiers_exact_then_bucket_then_shape():
    t = _mini_table()
    # tier 1: exact key
    assert t.resolve(TuningKey("stencil", "fine", "small")) == "pallas-fused"
    # tier 2: same (pattern, ndev, ngraphs), nearest bucket
    assert t.resolve(TuningKey("stencil", "medium", "small")) == \
        "pallas-fused"
    assert t.resolve(TuningKey("stencil", "coarse", "large")) == \
        "pallas-fused"  # ngraphs=1 keeps it in tier 2's g1 candidates
    # tier 3: same pattern only — nearest bucket, then nearest ngraphs
    assert t.resolve(TuningKey("stencil", "coarse", "large", ngraphs=3)) == \
        "shardmap-csp[comm=onesided]"
    assert t.resolve(TuningKey("stencil", "fine", "small", ndev=8)) == \
        "pallas-fused"
    # a pattern the table never saw is a miss, never a substitution
    assert t.resolve(TuningKey("trivial", "fine", "small")) is None
    assert t.entry(TuningKey("stencil", "medium", "small")) is None  # exact


def test_auto_resolve_spec_string_semantics(tmp_path):
    g = make_graph(width=4, height=6, pattern="stencil", iterations=1,
                   output_bytes=16)
    # non-auto specs pass straight through, whatever the table says
    assert auto_resolve("xla-static", [g]) == "xla-static"
    with pytest.raises(ValueError, match="known options"):
        auto_resolve("auto[grmbl=1]", [g])
    # explicit table= resolves from that table
    doc = build_tuning_table(smoke=True)
    path = write_tuning_json(doc, str(tmp_path))
    assert auto_resolve(f"auto[table={path}]", [g]) == "pallas-fused"
    # a pattern the table never tuned falls back (documented miss path)
    miss = make_graph(width=4, height=6, pattern="trivial", iterations=1)
    assert auto_resolve(f"auto[table={path}]", [miss]) == DEFAULT_FALLBACK
    assert auto_resolve(
        f"auto[fallback=host-dynamic,table={path}]", [miss]) == "host-dynamic"
    # a table tuned on another timer is refused, not silently trusted
    with pytest.raises(ValueError, match="timer"):
        auto_resolve(f"auto[table={path},timer=wallclock]", [g])


# ------------------------------------------------------- the committed table
def test_committed_table_pins_the_no_single_winner_claim():
    """The acceptance assertions: the fused megakernel owns the smallest
    granularity bucket (dispatch-bound, per-launch model undercuts every
    per-task runtime ~50x) and a one-sided comm spec owns the largest
    payload bucket (§V-F: rendezvous-free put/signal hides the wire)."""
    table = load_tuning_table(default_table_path())
    assert table.timer == "synthetic"
    fine = table.entry(TuningKey("stencil", "fine", "small"))
    assert fine is not None and fine["winner"] == "pallas-fused"
    assert fine["margin"] > 1.0  # not a squeaker: >2x over next-best
    big = table.entry(TuningKey("stencil", "medium", "large"))
    assert big is not None and "comm=onesided" in big["winner"]
    # every metg-family cell records the full legal mode space
    for key in table.keys():
        e = table.entry(key)
        if e["family"] == "metg":
            assert len(e["candidates"]) == len(enumerate_mode_space())


def test_diff_tuning_tables_gate_semantics():
    doc = build_tuning_table(smoke=True)
    fatal, notes = diff_tuning_tables(doc, doc)
    assert not fatal and not notes
    # changed winner at a shared key is fatal
    tampered = json.loads(json.dumps(doc))
    tampered["entries"][0]["winner"] = tampered["entries"][0]["candidates"][1][0]
    fatal, _ = diff_tuning_tables(doc, tampered)
    assert any("winner changed" in f for f in fatal)
    # a smoke regeneration against the full table: subset is notes-only
    full = build_tuning_table(smoke=False)
    fatal, notes = diff_tuning_tables(full, doc, subset_ok=True)
    assert not fatal and any("not retuned" in n for n in notes)
    fatal, _ = diff_tuning_tables(full, doc, subset_ok=False)
    assert any("missing" in f for f in fatal)
    # timer mismatch ends the comparison immediately
    wall = json.loads(json.dumps(doc))
    wall["timer"] = "wallclock"
    fatal, _ = diff_tuning_tables(wall, doc)
    assert any("timer changed" in f for f in fatal)


# ------------------------------------------------------------- auto backend
def test_auto_is_a_registered_backend_with_guarded_options(tmp_path):
    assert "auto" in backend_names()
    with pytest.raises(ValueError, match="cannot fall back to itself"):
        get_backend("auto[fallback=auto]")
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("auto[fallback=slurm]")
    # an explicit missing table fails at get_backend() time, not dispatch
    with pytest.raises(ValueError, match="not found"):
        get_backend(f"auto[table={tmp_path / 'TUNE_x.json'}]")


def test_auto_dispatch_is_bit_exact_with_its_resolved_backend():
    """The conformance cell: auto is pure delegation, so its outputs are
    bitwise identical to the backend the table resolves — the same
    invariant the cross-backend matrix asserts, one hop up."""
    be = get_backend("auto")
    for pattern, iters in (("stencil", 1), ("nearest", 2000)):
        g = make_graph(width=6, height=8, pattern=pattern, iterations=iters)
        spec = be.resolve_spec([g])
        assert spec != "auto"
        out = be.run([g])[0]
        check_outputs(g, out, expected=execute_reference(g))
        ref = get_backend(spec).run([g])[0]
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_auto_resolves_with_zero_per_dispatch_measurement():
    """Resolution must be a pure table lookup: no candidate backend is
    instantiated and nothing is timed on the resolve path."""
    be = get_backend("auto")
    g = make_graph(width=4, height=6, pattern="stencil", iterations=1)
    spec = be.resolve_spec([g])
    assert spec == "pallas-fused"
    assert be._delegates == {}  # resolve never built a backend
    be.delegate([g])
    assert list(be._delegates) == ["pallas-fused"]  # cached on execution


def test_synthetic_timer_charges_auto_as_its_resolved_backend():
    """The fake clock treats auto as the planner, not a cost model: an
    auto measurement equals the resolved winner's measurement exactly."""
    t = SyntheticTimer()
    g = make_graph(width=6, height=8, pattern="stencil", iterations=1,
                   output_bytes=16)
    resolved = auto_resolve("auto", [g])
    assert t.measure("auto", [g]) == t.measure(resolved, [g])
    # and the resolution is visible: at fine granularity the per-launch
    # model undercuts every per-task backend
    assert t.measure("auto", [g]) < t.measure("xla-scan", [g])


# ------------------------------------------------------------ the CLI paths
def test_run_only_rejects_unknown_modules(capsys):
    """Bugfix pin: a typo'd --only must exit nonzero naming the unknown
    entry and the registry — not silently run zero benchmarks."""
    from benchmarks.run import MODULES, main

    with pytest.raises(SystemExit) as exc:
        main(["--only", "bench_metg_pattens", "--artifacts", ""])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "bench_metg_pattens" in err
    assert "bench_metg_patterns" in err  # the registry is listed
    with pytest.raises(SystemExit) as exc:
        main(["--only", ",", "--artifacts", ""])
    assert exc.value.code == 2
    # a valid subset still runs (and prints its rows)


def test_run_only_valid_subset_still_runs(tmp_path, capsys):
    from benchmarks.run import main

    main(["--smoke", "--timer", "synthetic", "--only", "bench_peak",
          "--artifacts", str(tmp_path)])
    out = capsys.readouterr().out
    assert "bench_peak.elapsed" in out
    assert any(f.startswith("BENCH_") for f in os.listdir(tmp_path))


def test_tune_cli_round_trip_and_gate(tmp_path, capsys):
    from benchmarks.run import main

    art = tmp_path / "tune"
    main(["--tune", "--smoke", "--timer", "synthetic",
          "--artifacts", str(art)])
    out = capsys.readouterr().out
    assert "winner=pallas-fused" in out
    path = tuning_table_path(str(art))
    doc = read_tuning_json(path)
    # regenerating against itself passes; a directory baseline resolves
    main(["--tune", "--smoke", "--timer", "synthetic",
          "--artifacts", str(tmp_path / "tune2"),
          "--tune-baseline", str(art)])
    assert "winners match" in capsys.readouterr().out
    # a tampered committed winner trips the gate with exit 1
    doc["entries"][0]["winner"] = doc["entries"][0]["candidates"][-1][0]
    doc["entries"][0]["margin"] = 0.0
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(SystemExit) as exc:
        main(["--tune", "--smoke", "--timer", "synthetic",
              "--artifacts", str(tmp_path / "tune3"),
              "--tune-baseline", path])
    assert exc.value.code == 1
    assert "FATAL" in capsys.readouterr().out
    # --tune-baseline without --tune / --tune with --only are usage errors
    with pytest.raises(SystemExit) as exc:
        main(["--tune-baseline", path])
    assert exc.value.code == 2
    with pytest.raises(SystemExit) as exc:
        main(["--tune", "--only", "bench_peak"])
    assert exc.value.code == 2
