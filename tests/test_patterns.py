"""Property tests for dependence relations (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import get_pattern, make_graph, pattern_names

PATTERNS = pattern_names()


def _params_for(pattern):
    return {"radix": 5} if pattern in ("nearest", "spread") else {}


@settings(max_examples=30, deadline=None)
@given(
    pattern=st.sampled_from(PATTERNS),
    width=st.integers(1, 24),
    height=st.integers(1, 16),
)
def test_deps_within_bounds_sorted_unique(pattern, width, height):
    g = make_graph(width=width, height=height, pattern=pattern,
                   **_params_for(pattern))
    for t in range(height):
        for i in range(width):
            deps = g.deps(t, i)
            assert deps == sorted(set(deps))
            assert all(0 <= j < width for j in deps)
            if t == 0:
                assert deps == []


@settings(max_examples=30, deadline=None)
@given(
    pattern=st.sampled_from(PATTERNS),
    width=st.integers(1, 16),
    height=st.integers(2, 10),
)
def test_reverse_deps_is_transpose(pattern, width, height):
    """(t-1, j) in deps(t, i)  <=>  i in reverse_deps(t-1, j)."""
    g = make_graph(width=width, height=height, pattern=pattern,
                   **_params_for(pattern))
    for t in range(1, height):
        fwd = {(i, j) for i in range(width) for j in g.deps(t, i)}
        rev = {(i, j) for j in range(width)
               for i in g.reverse_deps(t - 1, j)}
        assert fwd == rev


@settings(max_examples=30, deadline=None)
@given(
    pattern=st.sampled_from(PATTERNS),
    width=st.integers(1, 16),
    t=st.integers(1, 8),
)
def test_matrix_matches_deps(pattern, width, t):
    g = make_graph(width=width, height=t + 1, pattern=pattern,
                   **_params_for(pattern))
    m = g.dependence_matrix(t)
    assert m.shape == (width, width)
    for i in range(width):
        assert sorted(np.nonzero(m[i])[0].tolist()) == g.deps(t, i)


@settings(max_examples=25, deadline=None)
@given(width=st.integers(1, 12), t=st.integers(0, 6))
def test_matrix_matches_deps_every_registered_pattern(width, t):
    """Matrix and set forms agree for *every* registered pattern at once
    (a new pattern joins this check just by registering), and deps stay
    inside [0, width)."""
    for pattern in PATTERNS:
        g = make_graph(width=width, height=t + 1, pattern=pattern,
                       **_params_for(pattern))
        m = g.dependence_matrix(t)
        assert m.shape == (width, width)
        for i in range(width):
            deps = g.deps(t, i)
            assert all(0 <= j < width for j in deps), (pattern, t, i)
            assert sorted(np.nonzero(m[i])[0].tolist()) == deps, (pattern, t, i)


@settings(max_examples=25, deadline=None)
@given(width=st.integers(1, 12), height=st.integers(1, 10))
def test_max_radix_is_true_upper_bound(width, height):
    """max_radix bounds len(deps(t, i)) over the whole iteration space and
    is attained (it is the exact max, not just an upper bound)."""
    for pattern in PATTERNS:
        g = make_graph(width=width, height=height, pattern=pattern,
                       **_params_for(pattern))
        radix = g.max_radix()
        observed = max(
            (len(g.deps(t, i))
             for t in range(height) for i in range(width)),
            default=0,
        )
        # equality: a true upper bound that is also attained (exact max)
        assert radix == observed, (pattern, radix, observed)


@settings(max_examples=40, deadline=None)
@given(
    width=st.integers(1, 16),
    ndev=st.sampled_from([1, 2, 4, 8]),
    height=st.integers(2, 8),
)
def test_a2a_plan_counts_are_a_permutation(width, ndev, height):
    """Token conservation for the a2a CommPlan mode, every registered
    pattern: the [src, dst] send-count matrix and the recv-count matrix
    are transposes (each row sent is received exactly once), counts match
    an independent recount from ``deps``, and nothing rides the diagonal.
    Ragged widths (width % ndev != 0, width < ndev) arise naturally."""
    from repro.dist import collectives as CC

    for pattern in PATTERNS:
        g = make_graph(width=width, height=height, pattern=pattern,
                       **_params_for(pattern))
        plan = CC.plan_comm(g, ndev, "cols", comm="a2a")
        sc, rc = plan.send_counts, plan.recv_counts
        assert sc.shape == rc.shape == (ndev, ndev), pattern
        assert (sc >= 0).all(), pattern
        assert (rc == sc.T).all(), pattern                # permutation
        assert sc.sum() == rc.sum(), pattern              # conservation
        assert (np.diag(sc) == 0).all(), pattern          # local rows stay
        # independent recount straight from the set-form dependence relation
        want = np.zeros((ndev, ndev), np.int64)
        seen = set()
        for t in range(1, height):
            for i in range(width):
                for j in g.deps(t, i):
                    s, d = j // plan.local, i // plan.local
                    if s != d and (s, d, j) not in seen:
                        seen.add((s, d, j))
                        want[s, d] += 1
        assert (sc == want).all(), (pattern, width, ndev)
        assert plan.a2a_cap == int(sc.max()), (pattern, width, ndev)


@settings(max_examples=25, deadline=None)
@given(
    width=st.integers(1, 16),
    imbalance=st.sampled_from([0.0, 1.5, 3.0]),
    workers=st.sampled_from([1, 3, 4, 8]),
)
def test_steal_dispatch_each_task_once_respecting_deps(width, imbalance,
                                                       workers):
    """The work-stealing executor's dispatch sequence, every registered
    pattern: each task issues exactly once, and every task issues after
    all of its dependencies (deps live in t-1; wavefronts are strictly
    ordered, within-wavefront the claim order is free)."""
    from repro.backends import get_backend

    be = get_backend("host-dynamic", schedule="steal", workers=workers)
    for pattern in PATTERNS:
        g = make_graph(width=width, height=5, pattern=pattern,
                       iterations=7, imbalance=imbalance,
                       **_params_for(pattern))
        trace = be.dispatch_order(g)
        expect = [(t, i) for t in range(g.height) for i in range(g.width)]
        assert sorted(trace) == expect, pattern  # exactly once each
        pos = {ti: k for k, ti in enumerate(trace)}
        for t in range(1, g.height):
            for i in range(g.width):
                for j in g.deps(t, i):
                    assert pos[(t - 1, j)] < pos[(t, i)], (pattern, t, i, j)


@settings(max_examples=40, deadline=None)
@given(
    ncols=st.integers(1, 24),
    workers=st.integers(1, 8),
    seed=st.integers(0, 5),
)
def test_steal_schedule_is_a_permutation_and_never_worse(ncols, workers,
                                                         seed):
    """core.schedule invariants: the claim order is a permutation, and
    the greedy makespan is bounded by serial above and by both the
    critical path and the perfect packing below (Graham's list-scheduling
    bound)."""
    import numpy as np

    from repro.core.schedule import steal_schedule, wavefront_makespan

    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.1, 10.0, size=ncols)
    order, start, makespan = steal_schedule(costs, workers)
    assert sorted(order) == list(range(ncols))
    assert (start >= 0).all()
    serial = wavefront_makespan(costs, workers, "serial")
    assert makespan <= serial + 1e-12
    lower = max(costs.max(), costs.sum() / workers)
    assert makespan >= lower - 1e-12
    # Graham's list-scheduling bound: sum/m + (1 - 1/m) * cmax
    assert makespan <= costs.sum() / workers \
        + (1 - 1.0 / workers) * costs.max() + 1e-12


def test_pattern_shapes_match_paper_table2():
    """Spot-check the Table 2 relations."""
    g = make_graph(width=8, height=8, pattern="stencil")
    assert g.deps(1, 3) == [2, 3, 4]
    assert g.deps(1, 0) == [0, 1]  # clipped at boundary
    g = make_graph(width=8, height=8, pattern="sweep")
    assert g.deps(1, 3) == [2, 3]
    g = make_graph(width=8, height=8, pattern="fft")
    assert g.deps(1, 2) == [1, 2, 3]      # stride 1
    assert g.deps(2, 2) == [0, 2, 4]      # stride 2
    assert g.deps(3, 2) == [2, 6]         # stride 4, clipped
    g = make_graph(width=8, height=8, pattern="trivial")
    assert all(g.deps(t, i) == [] for t in range(8) for i in range(8))


def test_random_pattern_deterministic():
    g1 = make_graph(width=8, height=8, pattern="random", seed=0)
    g2 = make_graph(width=8, height=8, pattern="random", seed=0)
    assert (g1.dependence_matrices() == g2.dependence_matrices()).all()


def test_contains_point():
    g = make_graph(width=4, height=5)
    assert g.contains_point(0, 0) and g.contains_point(4, 3)
    assert not g.contains_point(5, 0)
    assert not g.contains_point(-1, 0)
    assert not g.contains_point(0, 4)


def test_unknown_pattern_raises():
    with pytest.raises(KeyError):
        get_pattern("nope")
