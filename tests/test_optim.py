"""AdamW vs analytic reference; compression error-feedback properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.compression import ef_compress
from repro.optim import adamw


def _numpy_adamw(params, grads, steps, cfg):
    """Plain-numpy AdamW (fp32, no clip) for cross-checking."""
    mu = {k: np.zeros_like(v) for k, v in params.items()}
    nu = {k: np.zeros_like(v) for k, v in params.items()}
    p = {k: v.copy() for k, v in params.items()}
    for t in range(1, steps + 1):
        for k in p:
            g = grads[k]
            mu[k] = cfg.b1 * mu[k] + (1 - cfg.b1) * g
            nu[k] = cfg.b2 * nu[k] + (1 - cfg.b2) * g * g
            mh = mu[k] / (1 - cfg.b1 ** t)
            vh = nu[k] / (1 - cfg.b2 ** t)
            upd = mh / (np.sqrt(vh) + cfg.eps)
            if p[k].ndim >= 2:
                upd = upd + cfg.weight_decay * p[k]
            p[k] = p[k] - cfg.lr * upd
    return p


def test_adamw_matches_numpy_reference():
    rs = np.random.RandomState(0)
    params = {"w": rs.randn(4, 3).astype(np.float32),
              "b": rs.randn(3).astype(np.float32)}
    grads = {"w": rs.randn(4, 3).astype(np.float32) * 0.1,
             "b": rs.randn(3).astype(np.float32) * 0.1}
    cfg = adamw.AdamWConfig(lr=1e-2, clip_norm=1e9, weight_decay=0.1)
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    jg = {k: jnp.asarray(v) for k, v in grads.items()}
    state = adamw.init(jp, cfg)
    for _ in range(5):
        jp, state, _ = adamw.update(jg, state, jp, cfg)
    ref = _numpy_adamw(params, grads, 5, cfg)
    for k in ref:
        np.testing.assert_allclose(np.asarray(jp[k]), ref[k], rtol=2e-5,
                                   atol=2e-6)


def test_decay_mask_excludes_vectors():
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    mask = adamw.decay_mask(params)
    assert mask["w"] and not mask["scale"]


def test_grad_clipping():
    params = {"w": jnp.zeros((10,10))}
    grads = {"w": jnp.full((10, 10), 100.0)}
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    state = adamw.init(params, cfg)
    _, _, metrics = adamw.update(grads, state, params, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(1000.0, rel=1e-3)


def test_bf16_state_dtype():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    cfg = adamw.AdamWConfig(state_dtype="bfloat16", master_weights=False)
    state = adamw.init(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    assert state.master is None
    new_p, new_s, _ = adamw.update(
        {"w": jnp.ones((4, 4))}, state, params, cfg)
    assert new_p["w"].dtype == jnp.bfloat16


def test_master_weights_kept_fp32():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    cfg = adamw.AdamWConfig()
    state = adamw.init(params, cfg)
    assert state.master["w"].dtype == jnp.float32
    new_p, new_s, _ = adamw.update(
        {"w": jnp.full((4, 4), 1e-3)}, state, params, cfg)
    # master accumulates below bf16 resolution
    assert new_s.master["w"].dtype == jnp.float32


def test_error_feedback_compression_bound():
    """Compressed gradient + residual reconstructs the input exactly."""
    rs = np.random.RandomState(1)
    g = jnp.asarray(rs.randn(64, 64).astype(np.float32))
    res = jnp.zeros_like(g)
    comp, new_res = ef_compress(g, res)
    np.testing.assert_allclose(np.asarray(comp + new_res), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
    # quantization error bounded by scale/2 per element
    scale = float(jnp.abs(g).max()) / 127.0
    assert float(jnp.abs(new_res).max()) <= scale * 0.51 + 1e-7


def test_error_feedback_converges_on_constant_gradient():
    """With a constant gradient, EF-compressed sum approaches the true sum."""
    g = jnp.asarray(np.random.RandomState(2).randn(32).astype(np.float32))
    res = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        comp, res = ef_compress(g, res)
        total = total + comp
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=float(jnp.abs(g).max()) / 127.0)
