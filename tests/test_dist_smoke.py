"""1-device smoke tests for the dist subsystem (no subprocess harness)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.dist import pipeline as PP
from repro.dist.compression import compressed_psum, ef_compress_tree
from repro.optim import adamw


def test_compressed_psum_one_device():
    """Over a 1-axis the 'sum' is the value itself, up to int8 rounding."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    x = np.random.RandomState(0).randn(4, 32).astype(np.float32)
    f = jax.jit(shard_map(lambda v: compressed_psum(v, "d"),
                          mesh=mesh, in_specs=P("d"), out_specs=P("d")))
    got = np.asarray(f(jnp.asarray(x)))
    scale = np.abs(x).max() / 127.0
    assert np.abs(got - x).max() <= 0.51 * scale


def test_compressed_psum_zeros():
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    f = jax.jit(shard_map(lambda v: compressed_psum(v, "d"),
                          mesh=mesh, in_specs=P("d"), out_specs=P("d")))
    np.testing.assert_array_equal(
        np.asarray(f(jnp.zeros((2, 8)))), np.zeros((2, 8)))


def test_ef_compress_tree_reconstructs():
    rs = np.random.RandomState(3)
    grads = {"a": jnp.asarray(rs.randn(8, 8).astype(np.float32)),
             "b": jnp.asarray(rs.randn(16).astype(np.float32))}
    res = jax.tree.map(jnp.zeros_like, grads)
    comp, new_res = ef_compress_tree(grads, res)
    for k in grads:
        np.testing.assert_allclose(np.asarray(comp[k] + new_res[k]),
                                   np.asarray(grads[k]), rtol=1e-6, atol=1e-6)


def test_adamw_int8_ef_step_runs():
    """The optim hook into dist.compression, end-to-end on one step."""
    cfg = adamw.AdamWConfig(compression="int8_ef")
    params = {"w": jnp.ones((4, 4), jnp.float32)}
    state = adamw.init(params, cfg)
    assert state.ef_residual is not None
    grads = {"w": jnp.full((4, 4), 0.5, jnp.float32)}
    new_p, new_s, metrics = adamw.update(grads, state, params, cfg)
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(jnp.abs(new_p["w"] - params["w"]).sum()) > 0


def test_pp_schedule_shapes_and_wavefront():
    g = PP.pp_schedule(num_stages=3, num_micro=5)
    assert g.pattern == "sweep"
    assert g.width == 3 and g.height == 7
    # microbatch m hits stage s at tick t = m + s; deps are the arriving
    # activation (t-1, s-1) and the stage's previous microbatch (t-1, s)
    assert g.deps(2, 1) == [0, 1]
    assert g.deps(1, 0) == [0]
    assert g.deps(0, 0) == []


def test_stack_params_rejects_indivisible_depth():
    import pytest

    params = {"blocks_scanned": {"w": jnp.zeros((4, 2))}}
    stacked = PP.stack_params_by_stage(params, num_stages=2)
    assert stacked["blocks_scanned"]["w"].shape == (2, 2, 2)
    with pytest.raises(ValueError):
        PP.stack_params_by_stage(params, num_stages=3)
