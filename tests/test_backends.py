"""Cross-backend equivalence: every backend must reproduce the oracle."""
import numpy as np
import pytest

from repro.core import check_outputs, execute_reference, make_graph, replicate
from repro.backends import backend_names, get_backend

CASES = [
    dict(pattern="trivial"),
    dict(pattern="no_comm"),
    dict(pattern="stencil"),
    dict(pattern="sweep"),
    dict(pattern="fft"),
    dict(pattern="tree"),
    dict(pattern="random"),
    dict(pattern="nearest", radix=5),
    dict(pattern="spread", radix=3),
    dict(pattern="stencil", kernel="memory", span_bytes=256,
         scratch_bytes=2048),
    dict(pattern="stencil", kernel="compute_mxu", iterations=2, width=4),
    dict(pattern="nearest", radix=3, imbalance=0.8, iterations=32),
    dict(pattern="stencil", output_bytes=256),
    dict(pattern="stencil", kernel="empty"),
]


@pytest.fixture(scope="module")
def expected():
    cache = {}

    def get(graph):
        key = repr(graph)
        if key not in cache:
            cache[key] = execute_reference(graph)
        return cache[key]

    return get


@pytest.mark.parametrize("backend", backend_names())
@pytest.mark.parametrize("case", range(len(CASES)))
def test_backend_matches_oracle(backend, case, expected):
    kw = dict(CASES[case])
    kw.setdefault("width", 8)
    kw.setdefault("height", 10)
    kw.setdefault("iterations", 5)
    g = make_graph(**kw)
    out = get_backend(backend).run([g])[0]
    check_outputs(g, out, expected=expected(g))


@pytest.mark.parametrize("backend", backend_names())
def test_multiple_concurrent_graphs(backend, expected):
    """Paper Fig 9d: concurrent task graphs (task parallelism)."""
    g = make_graph(width=4, height=8, pattern="nearest", radix=3,
                   iterations=4)
    graphs = replicate(g, 3)
    outs = get_backend(backend).run(graphs)
    assert len(outs) == 3
    e = expected(g)
    for o in outs:
        check_outputs(g, o, expected=e)


@pytest.mark.parametrize("backend", backend_names())
def test_heterogeneous_concurrent_graphs(backend):
    gs = [
        make_graph(width=4, height=6, pattern="stencil", iterations=3),
        make_graph(width=8, height=5, pattern="spread", radix=3,
                   iterations=7, output_bytes=64),
    ]
    outs = get_backend(backend).run(gs)
    for g, o in zip(gs, outs):
        check_outputs(g, o)


def test_backend_spec_strings():
    """get_backend accepts 'name[key=value,...]' — the form
    ScenarioSpec.backend and the Timer protocol carry mode options in."""
    from repro.backends.base import parse_backend_spec

    assert parse_backend_spec("xla-scan") == ("xla-scan", {})
    assert parse_backend_spec("host-dynamic[schedule=steal,workers=2]") == \
        ("host-dynamic", {"schedule": "steal", "workers": 2})
    assert parse_backend_spec("shardmap-csp[comm_overlap=True]") == \
        ("shardmap-csp", {"comm_overlap": True})
    be = get_backend("host-dynamic[schedule=steal,workers=2]")
    assert be.schedule == "steal" and be.workers == 2
    assert be.sched_policy == "steal"
    # explicit kwargs override spec-string options
    be = get_backend("host-dynamic[schedule=steal]", schedule="static")
    assert be.schedule == "static" and be.sched_policy == "static"
    be = get_backend("shardmap-csp[comm_overlap=True]")
    assert be.comm_overlap is True
    # JSON/YAML boolean spellings must not fall through to truthy strings
    assert parse_backend_spec("x[a=false,b=TRUE]") == \
        ("x", {"a": False, "b": True})
    assert get_backend("shardmap-csp[comm_overlap=false]").comm_overlap \
        is False
    with pytest.raises(KeyError, match="unknown backend"):
        get_backend("no-such-backend[comm_overlap=True]")
    with pytest.raises(ValueError, match="malformed"):
        get_backend("host-dynamic[schedule]")
    with pytest.raises(ValueError, match="malformed"):
        get_backend("host-dynamic[")
    with pytest.raises(ValueError):
        get_backend("host-dynamic", schedule="nope")


def test_backend_spec_canonicalization():
    """Option order inside the spec string is never identity: the parsed
    kwargs come back key-sorted and canonical_backend_spec renders
    key-reordered spellings to one string."""
    from repro.backends.base import canonical_backend_spec, parse_backend_spec

    a = parse_backend_spec("host-dynamic[workers=2,schedule=steal]")
    b = parse_backend_spec("host-dynamic[schedule=steal,workers=2]")
    assert a == b
    assert list(a[1]) == ["schedule", "workers"]  # key-sorted
    assert (canonical_backend_spec("host-dynamic[workers=2,schedule=steal]")
            == canonical_backend_spec("host-dynamic[schedule=steal,workers=2]")
            == "host-dynamic[schedule=steal,workers=2]")
    # bare names and single options render unchanged, values in the
    # spelling that re-parses to the same kwargs
    assert canonical_backend_spec("xla-scan") == "xla-scan"
    spec = canonical_backend_spec(
        "shardmap-csp[comm_overlap=True,comm=onesided]")
    assert spec == "shardmap-csp[comm=onesided,comm_overlap=True]"
    assert parse_backend_spec(spec) == parse_backend_spec(
        "shardmap-csp[comm_overlap=True,comm=onesided]")
    with pytest.raises(ValueError, match="malformed"):
        canonical_backend_spec("x[[")
    # the canonical spec still resolves to the same backend configuration
    be = get_backend(spec)
    assert be.comm == "onesided" and be.comm_overlap is True


def test_backend_spec_rejects_duplicate_keys():
    """A spec that sets the same option twice is a typo'd scenario, not a
    last-wins preference — the error names the key and the full spec."""
    from repro.backends.base import parse_backend_spec

    with pytest.raises(ValueError, match=r"duplicate option 'workers'"):
        parse_backend_spec("host-dynamic[workers=2,workers=4]")
    with pytest.raises(ValueError, match=r"host-dynamic\[schedule=steal"):
        get_backend("host-dynamic[schedule=steal,schedule=static]")


def test_backend_spec_rejects_unknown_ctor_options():
    """Options the constructor doesn't accept fail loudly, naming the
    backend and the option (silently-ignored typos poison sweeps)."""
    with pytest.raises(ValueError, match=r"'host-dynamic'.*'workres'"):
        get_backend("host-dynamic[workres=2]")
    # the error enumerates the legal options to fix the typo against
    with pytest.raises(ValueError, match="schedule"):
        get_backend("host-dynamic[workres=2]")
    # ...and says so when the backend takes none at all
    with pytest.raises(ValueError, match="known options: none"):
        get_backend("xla-scan[bogus=1]")
    # explicit kwargs go through the same validation as spec strings
    with pytest.raises(ValueError, match=r"'xla-scan'.*'bogus'"):
        get_backend("xla-scan", bogus=1)
    # legal options still pass on every constructor shape
    assert get_backend("pallas-fused[interpret=True]").interpret is True
    assert get_backend("host-dynamic[workers=3]").workers == 3


def test_validation_catches_corruption():
    g = make_graph(width=4, height=6, pattern="stencil", iterations=3)
    out = get_backend("xla-scan").run([g])[0].copy()
    out[2, 3] += 1.0  # corrupt the combined checksum
    with pytest.raises(AssertionError):
        check_outputs(g, out)
