"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernel_ref import run_kernel_ref
from repro.core.kernel_spec import KernelSpec
from repro.kernels import ops, ref


@pytest.mark.parametrize("width,max_iters", [(8, 12), (16, 40), (32, 7)])
def test_taskbench_compute_kernel(width, max_iters):
    tiles = jnp.full((width, 8, 128), 0.5, jnp.float32)
    iters = jnp.asarray(
        np.random.RandomState(width).randint(1, max_iters + 1, width),
        jnp.int32)
    out_k = ops.taskbench_compute(tiles, iters, max_iters, impl="interpret")
    out_r = ops.taskbench_compute(tiles, iters, max_iters, impl="ref")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6)
    exp = np.array([run_kernel_ref(KernelSpec(kind="compute"), int(i))
                    for i in iters], np.float32)
    np.testing.assert_allclose(np.asarray(out_k)[:, 0, 0], exp, rtol=1e-6)


@pytest.mark.parametrize("size,span,iters", [(1024, 128, 7), (2048, 256, 0),
                                             (512, 512, 9)])
def test_taskbench_memory_kernel(size, span, iters):
    x = jnp.arange(size, dtype=jnp.float32) / size
    a = ops.taskbench_memory(x, iters, span, impl="interpret")
    b = ops.taskbench_memory(x, iters, span, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


ATTN_CASES = [
    # B, Sq, Skv, Hq, Hkv, D, causal, window, q_offset, dtype
    (2, 128, 128, 4, 2, 64, True, None, 0, jnp.float32),
    (1, 128, 256, 8, 8, 32, True, 64, 128, jnp.float32),
    (2, 64, 64, 4, 1, 64, False, None, 0, jnp.float32),
    (1, 256, 256, 2, 2, 128, True, 128, 0, jnp.bfloat16),
    (2, 128, 128, 6, 3, 64, True, None, 0, jnp.float32),
]


@pytest.mark.parametrize("case", range(len(ATTN_CASES)))
def test_flash_attention_kernel(case):
    B, Sq, Skv, Hq, Hkv, D, causal, win, qoff, dt = ATTN_CASES[case]
    ks = jax.random.split(jax.random.PRNGKey(case), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dt)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dt)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dt)
    o_k = ops.attention(q, k, v, causal=causal, window=win, q_offset=qoff,
                        impl="interpret", block_q=64, block_k=64)
    o_r = ops.attention(q, k, v, causal=causal, window=win, q_offset=qoff,
                        impl="ref")
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o_k, np.float32), np.asarray(o_r, np.float32),
        rtol=tol, atol=tol)


def test_attention_chunked_matches_dense():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 2048, 4, 32))
    k = jax.random.normal(ks[1], (1, 2048, 2, 32))
    v = jax.random.normal(ks[2], (1, 2048, 2, 32))
    a = ref.attention_ref(q, k, v, causal=True, window=512)
    b = ref.attention_ref_chunked(q, k, v, causal=True, window=512,
                                  q_chunk=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


SSD_CASES = [
    # B, S, H, P, G, N, chunk
    (2, 128, 4, 16, 2, 8, 32),
    (1, 256, 8, 32, 1, 16, 64),
    (2, 64, 2, 64, 2, 32, 64),
]


@pytest.mark.parametrize("case", range(len(SSD_CASES)))
def test_ssd_kernel(case):
    B, S, H, P, G, N, chunk = SSD_CASES[case]
    ks = jax.random.split(jax.random.PRNGKey(case), 6)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    D = jax.random.normal(ks[5], (H,))
    y_k, h_k = ops.ssd(x, dt, A, Bm, Cm, D, chunk=chunk, impl="interpret")
    y_s, h_s = ref.ssd_ref(x, dt, A, Bm, Cm, D, return_state=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_s), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_s), rtol=2e-3,
                               atol=2e-3)


def test_ssd_ragged_padding():
    """ops.ssd pads to chunk multiples without corrupting the final state."""
    B, S, H, P, G, N = 1, 100, 2, 8, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y_p, h_p = ops.ssd(x, dt, A, Bm, Cm, chunk=32, impl="ref")
    y_s, h_s = ref.ssd_ref(x, dt, A, Bm, Cm, return_state=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_s), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_s), rtol=2e-4,
                               atol=2e-4)


def test_ssd_decode_step_matches_scan():
    B, S, H, P, G, N = 2, 16, 2, 8, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y_full, h_full = ref.ssd_ref(x, dt, A, Bm, Cm, return_state=True)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y_t, h = ops.ssd_decode_step(
            x[:, t:t+1], dt[:, t:t+1], A, Bm[:, t:t+1], Cm[:, t:t+1], h)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
