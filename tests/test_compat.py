"""The JAX compat shim, plus a guard against bypassing it."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import pcast, shard_map

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_shard_map_runs_on_one_device():
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    f = shard_map(lambda v: jax.lax.psum(v, "d"),
                  mesh=mesh, in_specs=P("d"), out_specs=P("d"))
    x = jnp.arange(8, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))


def test_shard_map_accepts_check_vma_kwarg():
    """check_vma must be translated to check_rep on legacy JAX."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    f = shard_map(lambda v: v * 2, mesh=mesh, in_specs=P("d"),
                  out_specs=P("d"), check_vma=False)
    x = jnp.ones((4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)), 2 * np.ones(4))


def test_pcast_is_usable_outside_shard_map_semantics():
    """On legacy JAX pcast is the identity; either way values round-trip."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))

    def body(v):
        v = pcast(v, ("d",), to="varying")
        return v + 1

    f = shard_map(body, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
    x = jnp.zeros((4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(f(x)), np.ones(4))


def test_no_direct_jax_shard_map_references_in_src():
    """Everything under src/ must go through repro.compat."""
    import re

    banned = re.compile(
        r"jax\.shard_map"                       # attribute access
        r"|jax\.lax\.pcast|lax\.pcast"          # pcast in any spelling
        r"|jax\.experimental\.shard_map"        # legacy module, any form
        r"|from\s+jax\s+import\s+.*\bshard_map\b"
        r"|from\s+jax\.lax\s+import\s+.*\bpcast\b")
    offenders = []
    for root, _, files in os.walk(SRC):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(root, name)
            if os.path.basename(path) == "compat.py":
                continue
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    if banned.search(line.split("#", 1)[0]):
                        offenders.append(f"{path}:{lineno}")
    assert not offenders, (
        "direct jax shard_map/pcast use (import repro.compat "
        f"instead): {offenders}")
