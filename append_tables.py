"""Append generated result tables to EXPERIMENTS.md.

Two generators share the ``## §Tables (generated)`` marker (everything
after it is machine-written; text above survives):

* ``append_metg_tables`` — the paper-style METG(50%) summary (backend x
  case, one table per scenario family) aggregated from the
  ``BENCH_*.json`` artifacts a sweep wrote, plus the committed
  auto-backend tuning winners (``benchmarks/tuning/TUNE_*.json``).
  Wired to ``benchmarks/run.py --tables``.
* ``append_dryrun_tables`` — the legacy roofline tables from
  ``results/dryrun.json`` (production-mesh studies).
"""
from __future__ import annotations

import argparse
import glob
import os
import sys
from collections import defaultdict
from typing import Dict, List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "src"))

MARKER = "## §Tables (generated)"


def load_metg_artifacts(artifacts_dir: str) -> Tuple[List[Dict], int]:
    """``(docs, skipped)``: schema-valid ``BENCH_*.json`` docs under
    ``artifacts_dir`` plus the count of files that failed validation.

    A corrupt or foreign artifact is not a table row, but silently
    dropping it makes a backend row vanish from EXPERIMENTS.md with no
    signal — each skip warns on stderr naming the path and reason, and
    the count is returned so callers (``run.py --tables``) can surface
    it next to the spliced-tables line.
    """
    from repro.bench.artifact import read_bench_json

    docs: List[Dict] = []
    skipped = 0
    for path in sorted(glob.glob(os.path.join(artifacts_dir,
                                              "BENCH_*.json"))):
        try:
            docs.append(read_bench_json(path))
        except ValueError as e:
            skipped += 1
            print(f"append_tables: skipping {path}: {e}", file=sys.stderr)
    return docs, skipped


def _case_name(scenario: Dict) -> str:
    """The column label: the scenario name minus family and backend
    segments (``metg.xla-scan.stencil`` -> ``stencil``)."""
    parts = scenario["name"].split(".")
    rest = [p for p in parts[1:] if p != scenario["backend"]]
    return ".".join(rest) or scenario["pattern"]


def render_metg_summary(docs: List[Dict]) -> str:
    """Markdown METG(50%) tables, one per scenario family (µs cells;
    ``>sweep`` marks a curve that never reached 50% in its range —
    the floor sits above the whole sweep)."""
    families: Dict[str, Dict] = defaultdict(dict)
    for doc in docs:
        if doc.get("kind") != "metg_sweep":
            continue  # serve_load docs render via render_serve_summary
        sc = doc["scenario"]
        families[sc["name"].split(".")[0]][(sc["backend"],
                                           _case_name(sc))] = doc
    out = []
    for fam in sorted(families):
        cells = families[fam]
        backends = sorted({b for b, _ in cells})
        cases = sorted({c for _, c in cells})
        out.append(f"\n### METG(50%) — {fam} (µs; '>sweep' = no 50% "
                   f"crossing in the sweep range)\n")
        out.append("| backend | " + " | ".join(cases) + " |")
        out.append("|---" * (len(cases) + 1) + "|")
        for b in backends:
            row = [b]
            for c in cases:
                doc = cells.get((b, c))
                if doc is None:
                    row.append("—")
                elif doc["metg_s"] is None:
                    row.append(">sweep")
                else:
                    row.append(f"{doc['metg_s'] * 1e6:.2f}")
            out.append("| " + " | ".join(row) + " |")
        out.append("")
    return "\n".join(out)


def render_serve_summary(docs: List[Dict]) -> str:
    """Markdown serve_load table: decode mode x arrival rate, percentile
    latencies + decode throughput + host syncs per token (empty string
    when no serve_load artifacts are present)."""
    cells = {}
    for doc in docs:
        if doc.get("kind") != "serve_load":
            continue
        sc = doc["scenario"]
        cells[(sc["mode"], float(sc["rate_rps"]))] = doc
    if not cells:
        return ""
    out = [
        "\n### serve_load — open-loop serving latency "
        "(host per-token loop vs on-device chunked decode)\n",
        "| mode | rate (req/s) | TTFT p50/p95 (ms) | TPOT p50/p95 (µs) "
        "| thr (tok/s) | goodput (req/s) | syncs/token |",
        "|---|---|---|---|---|---|---|",
    ]
    for (mode, rate) in sorted(cells, key=lambda k: (k[0], k[1])):
        m = cells[(mode, rate)]["metrics"]
        out.append(
            f"| {mode} | {rate:g} "
            f"| {m['ttft_s']['p50'] * 1e3:.3f}/{m['ttft_s']['p95'] * 1e3:.3f} "
            f"| {m['tpot_s']['p50'] * 1e6:.1f}/{m['tpot_s']['p95'] * 1e6:.1f} "
            f"| {m['throughput_tok_s']:.0f} "
            f"| {m['goodput_rps']:.0f} "
            f"| {m['host_syncs_per_token']:.3f} |")
    out.append("")
    return "\n".join(out)


def render_scaling_summary(docs: List[Dict]) -> str:
    """Markdown weak-scaling table: one row per ``metg_scaling`` series,
    weak-scaling efficiency ``T(1)/T(n)`` per rank count at the coarsest
    granularity, plus the finest-granularity efficiency at the top rank
    count (the contour's floor corner).  Empty string when no
    ``metg_scaling`` artifacts are present."""
    series = [d for d in docs if d.get("kind") == "metg_scaling"]
    if not series:
        return ""
    ranks = sorted({c["ranks"] for d in series for c in d["cells"]})
    out = [
        "\n### Weak scaling — metg_scaling (fixed work per rank; "
        "weak-scaling efficiency T(1)/T(n), ideal 1.0)\n",
        "| backend | " + " | ".join(f"r={n}" for n in ranks)
        + " | eff@finest (top ranks) |",
        "|---" * (len(ranks) + 2) + "|",
    ]
    for d in sorted(series, key=lambda d: d["scenario"]["name"]):
        cells = {c["ranks"]: c for c in d["cells"]}
        row = [d["scenario"]["backend"]]
        for n in ranks:
            c = cells.get(n)
            row.append("—" if c is None else f"{c['weak_efficiency']:.3f}")
        top = cells[max(cells)]
        fine = min(top["points"], key=lambda p: p["iterations"])
        row.append(f"{fine['weak_efficiency']:.3f} "
                   f"@ {fine['granularity_s'] * 1e6:.2f} µs")
        out.append("| " + " | ".join(row) + " |")
    out.append("")
    return "\n".join(out)


def render_tuning_summary(tuning_dir: str = "benchmarks/tuning") -> str:
    """Markdown table of the committed planner winners: one row per
    tuning key, grouped by family (what ``get_backend("auto")``
    dispatches where, and by how much the winner beat the runner-up).
    Empty string when no committed table exists."""
    from repro.bench.tuner import (TuningKey, key_order, key_slug,
                                   read_tuning_json, tuning_table_path)

    path = tuning_table_path(tuning_dir)
    if not os.path.exists(path):
        return ""
    doc = read_tuning_json(path)
    by_family: Dict[str, List[Dict]] = defaultdict(list)
    for e in doc["entries"]:
        by_family[e["family"]].append(e)
    out = [
        f"\n### Auto-backend tuning winners — timer {doc['timer']} "
        f"(`get_backend(\"auto\")` dispatch table; margin = cost of the "
        f"next-best distinct candidate)\n",
    ]
    for fam in sorted(by_family):
        out.append(f"\n#### {fam}\n")
        out.append("| tuning key | winner | elapsed (µs) | margin |")
        out.append("|---|---|---|---|")
        entries = sorted(by_family[fam],
                         key=lambda e: key_order(TuningKey(**e["key"])))
        for e in entries:
            out.append(
                f"| {key_slug(TuningKey(**e['key']))} | `{e['winner']}` "
                f"| {e['elapsed_s'] * 1e6:.2f} | +{e['margin']:.1%} |")
        out.append("")
    return "\n".join(out)


def _splice(md_path: str, body: str) -> str:
    """Replace everything after the marker with ``body`` (creating the
    file, or the marker section, when missing)."""
    if os.path.exists(md_path):
        text = open(md_path).read()
    else:
        text = "# Experiments\n\n" + MARKER + "\n"
    if MARKER not in text:
        text = text.rstrip() + "\n\n" + MARKER + "\n"
    text = text[: text.index(MARKER) + len(MARKER)] + "\n" + body
    with open(md_path, "w") as f:
        f.write(text)
    return md_path


def append_metg_tables(artifacts_dir: str,
                       md_path: str = "EXPERIMENTS.md",
                       tuning_dir: str = None) -> Tuple[str, int]:
    """Aggregate ``BENCH_*.json`` under ``artifacts_dir`` into the METG,
    serve-load and weak-scaling summaries (plus the committed
    auto-backend tuning winners) and splice them into ``md_path``;
    returns ``(path_written, artifacts_skipped)``."""
    docs, skipped = load_metg_artifacts(artifacts_dir)
    if not docs:
        raise ValueError(
            f"no valid BENCH_*.json artifacts in {artifacts_dir!r}"
            + (f" ({skipped} skipped as invalid)" if skipped else ""))
    if tuning_dir is None:
        tuning_dir = os.path.join(
            os.path.dirname(os.path.abspath(__file__)),
            "benchmarks", "tuning")
    path = _splice(md_path,
                   render_metg_summary(docs) + render_serve_summary(docs)
                   + render_scaling_summary(docs)
                   + render_tuning_summary(tuning_dir) + "\n")
    return path, skipped


def append_dryrun_tables(dryrun_json: str = "results/dryrun.json",
                         md_path: str = "EXPERIMENTS.md") -> str:
    """Legacy roofline tables from the compiled dry-run results."""
    import json

    from repro.launch.report import (hbm_total_gb, render_dryrun_table,
                                     render_roofline_table, row_terms)

    results = json.load(open(dryrun_json))
    out = []
    out.append("\n### Roofline — single pod 16x16 (256 chips), "
               "strategy tp+fsdp+sp\n")
    out.append("(memory term excludes Pallas-flash-eliminated "
               "attention-quadratic traffic; decode rows score bandwidth "
               "fraction — see §Roofline)\n")
    out.append(render_roofline_table(results, "pod16x16", "tp+fsdp+sp"))
    out.append("\n\n### Strategy comparison — qwen1.5-0.5b train_4k "
               "(§Perf B)\n")
    out.append("| strategy | compute_s | memory_s | collective_s | "
               "bound_s | frac | HBM GB |")
    out.append("|---|---|---|---|---|---|---|")
    for strat in ("tp+fsdp+sp", "dp_heavy", "dp_mod"):
        key = f"qwen1.5-0.5b|train_4k|pod16x16|{strat}"
        v = results.get(key)
        if not v or v["status"] != "ok":
            continue
        t = row_terms(v)
        out.append(
            f"| {strat} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | {t['bound_step_s']:.3f} "
            f"| {t['roofline_fraction'] * 100:.2f}% | {hbm_total_gb(v):.1f} |")
    out.append("\n\n### Dry-run detail — both meshes, strategy tp+fsdp+sp\n")
    out.append(render_dryrun_table(results, "tp+fsdp+sp"))
    out.append("")
    return _splice(md_path, "\n".join(out))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default=None,
                    help="BENCH_*.json directory -> METG summary tables")
    ap.add_argument("--dryrun-json", default=None,
                    help="results/dryrun.json -> legacy roofline tables")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    args = ap.parse_args(argv)
    if not args.artifacts and not args.dryrun_json:
        ap.error("nothing to do: pass --artifacts and/or --dryrun-json")
    if args.artifacts:
        path, skipped = append_metg_tables(args.artifacts, args.out)
        note = f" ({skipped} invalid artifact(s) skipped)" if skipped else ""
        print(f"tables appended: {path}{note}")
    if args.dryrun_json:
        print(f"tables appended: "
              f"{append_dryrun_tables(args.dryrun_json, args.out)}")


if __name__ == "__main__":
    main()
