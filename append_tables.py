"""Append generated §Tables to EXPERIMENTS.md from results/dryrun.json."""
import json, sys
sys.path.insert(0, "src")
from repro.launch.report import (render_dryrun_table, render_roofline_table,
                                 row_terms, hbm_total_gb)

results = json.load(open("results/dryrun.json"))

out = []
out.append("\n### Roofline — single pod 16x16 (256 chips), strategy tp+fsdp+sp\n")
out.append("(memory term excludes Pallas-flash-eliminated attention-quadratic "
           "traffic; decode rows score bandwidth fraction — see §Roofline)\n")
out.append(render_roofline_table(results, "pod16x16", "tp+fsdp+sp"))
out.append("\n\n### Strategy comparison — qwen1.5-0.5b train_4k (§Perf B)\n")
out.append("| strategy | compute_s | memory_s | collective_s | bound_s | frac | HBM GB |")
out.append("|---|---|---|---|---|---|---|")
for strat in ("tp+fsdp+sp", "dp_heavy", "dp_mod"):
    key = f"qwen1.5-0.5b|train_4k|pod16x16|{strat}"
    v = results.get(key)
    if not v or v["status"] != "ok":
        continue
    t = row_terms(v)
    out.append(f"| {strat} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
               f"| {t['collective_s']:.3f} | {t['bound_step_s']:.3f} "
               f"| {t['roofline_fraction']*100:.2f}% | {hbm_total_gb(v):.1f} |")
out.append("\n\n### Dry-run detail — both meshes, strategy tp+fsdp+sp\n")
out.append(render_dryrun_table(results, "tp+fsdp+sp"))
out.append("")

text = open("EXPERIMENTS.md").read()
marker = "## §Tables (generated)"
text = text[: text.index(marker) + len(marker)] + "\n" + "\n".join(out)
open("EXPERIMENTS.md", "w").write(text)
print("tables appended")
