"""Paper Figure 14 / Table 6: predicting the scaling limit from METG.

The paper's claim: one full-size run plus the METG curve predicts where
strong scaling stops (within ~2x in node count, ~1.3x in time).  The
1-core analogue: strong-scaling a fixed total problem over n virtual
workers shrinks per-task granularity as work/n; the efficiency-limited
wall-time floor is METG(50%) x tasks.  We predict the largest useful n
from (one big run + METG), then measure where the actual curve crosses
the floor, and report the factor of separation — Table 6's statistic.

Both measurements are ``repro.bench`` scenarios: the METG curve is the
standard geometric sweep, and the strong-scaling curve is the same graph
family swept over the per-worker task sizes ``TOTAL/n``.
"""
from __future__ import annotations

from typing import List

from repro.bench import ScenarioSpec, SweepControls

from .common import BenchContext, Row

TOTAL_ITERS = 16384  # total work per column-task-chain
HEIGHT = 32
NS = [1, 2, 4, 8, 16, 32, 64, 128, 256]


def _spec(name: str, schedule) -> ScenarioSpec:
    return ScenarioSpec(
        name=name, backend="xla-scan", pattern="stencil", kernel="compute",
        width=8, height=HEIGHT,
        sweep=SweepControls(schedule=tuple(schedule)),
    )


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    rows: List[Row] = []

    # METG curve (measured in place, same shape)
    metg_res = ctx.run(_spec("metg_validation.curve",
                             (4096, 1024, 256, 64, 16, 4, 1))).metg
    metg = metg_res.metg or 0.0
    num_tasks = metg_res.points[0].num_tasks if metg_res.points else 8 * HEIGHT

    # "strong scaling": n virtual workers -> per-task work TOTAL/n
    scaling = ctx.run(_spec("metg_validation.strong_scaling",
                            [max(1, TOTAL_ITERS // n) for n in NS])).metg
    walls = {p.iterations: p.wall_time for p in scaling.points}
    actual = {}
    for n in NS:
        iters = max(1, TOTAL_ITERS // n)
        if iters not in walls:  # smoke mode truncates the schedule
            continue
        actual[n] = walls[iters] / n  # per-worker wall share (ideal parallel)
        rows.append(Row(f"metg_validation.actual.n{n}", actual[n] * 1e6,
                        f"iters_per_task={iters}"))

    # prediction: ideal time = t(1)/n; limit floor = METG x per-chain tasks
    t1 = actual.get(1)
    if t1 is None and actual:  # smoke: estimate serial time from largest task
        # actual[n] = wall(TOTAL/n)/n and wall(i) ~ i (compute-dominant),
        # so t(1) = wall(TOTAL) ~ wall(TOTAL/n0) * n0 = actual[n0] * n0^2
        n0 = min(actual)
        t1 = actual[n0] * n0 * n0
    floor = metg * num_tasks / 8  # per-column-chain share
    pred_n = (t1 / floor) if (t1 and floor > 0) else float("inf")
    # measured crossing: first n whose actual per-worker time <= floor
    meas_n = None
    for n in sorted(actual):
        if actual[n] <= floor * 1.05:
            meas_n = n
            break
    meas_n = meas_n or (max(actual) if actual else NS[-1])
    sep = max(pred_n, meas_n) / max(min(pred_n, meas_n), 1e-9)
    rows.append(Row("metg_validation.summary", metg * 1e6,
                    f"pred_limit_n={pred_n:.1f};measured_limit_n={meas_n};"
                    f"separation_factor={sep:.2f}"))
    return rows
