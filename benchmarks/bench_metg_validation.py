"""Paper Figure 14 / Table 6: predicting the scaling limit from METG.

The paper's claim: one full-size run plus the METG curve predicts where
strong scaling stops (within ~2x in node count, ~1.3x in time).  The
1-core analogue: strong-scaling a fixed total problem over n virtual
workers shrinks per-task granularity as work/n; the efficiency-limited
wall-time floor is METG(50%) x tasks.  We predict the largest useful n
from (one big run + METG), then measure where the actual curve crosses
the floor, and report the factor of separation — Table 6's statistic.
"""
from __future__ import annotations

import math
from typing import List

from repro.backends import get_backend
from repro.core import compute_metg, make_graph, run_sweep

from .common import Row

TOTAL_ITERS = 16384  # total work per column-task-chain
HEIGHT = 32


def run() -> List[Row]:
    rows: List[Row] = []
    be = get_backend("xla-scan")

    def graphs_at(iters):
        return [make_graph(width=8, height=HEIGHT, pattern="stencil",
                           kernel="compute", iterations=iters)]

    def make_runner(iters):
        return be.prepare(graphs_at(iters))

    # METG curve (measured in place, same shape)
    sweep_sizes = [4096, 1024, 256, 64, 16, 4, 1]
    pts = run_sweep(make_runner, graphs_at, sweep_sizes, repeats=3)
    res = compute_metg(pts)
    metg = res.metg or 0.0
    num_tasks = 8 * HEIGHT

    # "strong scaling": n virtual workers -> per-task work TOTAL/n
    ns = [1, 2, 4, 8, 16, 32, 64, 128, 256]
    actual = {}
    for n in ns:
        iters = max(1, TOTAL_ITERS // n)
        runner = make_runner(iters)
        runner()
        import time
        best = min(
            (lambda: (lambda t0: (runner(), time.perf_counter() - t0)[1])(
                time.perf_counter()))()
            for _ in range(3))
        actual[n] = best / n  # per-worker wall share (ideal parallel time)
        rows.append(Row(f"metg_validation.actual.n{n}", best / n * 1e6,
                        f"iters_per_task={iters}"))

    # prediction: ideal time = t(1)/n; limit floor = METG * tasks / ...
    t1 = actual[1]
    floor = metg * num_tasks / 8  # per-column-chain share
    pred_n = t1 / floor if floor > 0 else float("inf")
    # measured crossing: first n whose actual per-worker time <= floor*1.0
    meas_n = None
    for n in ns:
        if actual[n] <= floor * 1.05:
            meas_n = n
            break
    meas_n = meas_n or ns[-1]
    sep = max(pred_n, meas_n) / max(min(pred_n, meas_n), 1e-9)
    rows.append(Row("metg_validation.summary", metg * 1e6,
                    f"pred_limit_n={pred_n:.1f};measured_limit_n={meas_n};"
                    f"separation_factor={sep:.2f}"))
    return rows
