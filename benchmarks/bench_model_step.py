"""Framework METG: the paper's metric applied to our own runtime.

Treats one transformer block as the "task" and sweeps model size (layer
count fixed, per-layer work varied via seq length) measuring the train-step
dispatch floor — the granularity below which the JAX dispatch overhead
(python + runtime) eats >50% of the step.  This is the number a user needs
to pick microbatch sizes on real hardware, and the direct analogue of the
paper's §V-C question asked of this framework itself.

Not a task-graph scenario (the "graph" here is the model), but timing goes
through ``repro.bench.time_run`` and smoke mode comes from the context.
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.bench import time_run
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, make_batch
from repro.train import train_step as TS

from .common import BenchContext, Row

ARCHS = ["qwen1.5-0.5b", "mixtral-8x7b", "mamba2-2.7b"]
SEQS = (16, 64, 256)


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    archs = ARCHS[:1] if ctx.smoke else ARCHS
    seqs = SEQS[:1] if ctx.smoke else SEQS
    repeats = 1 if ctx.smoke else 3
    rows: List[Row] = []
    for arch in archs:
        cfg = reduced(get_config(arch))
        tcfg = TS.TrainConfig(total_steps=100)
        state, _ = TS.init_state(jax.random.PRNGKey(0), cfg, tcfg)
        step = TS.jit_train_step(cfg, tcfg)
        per_layer = []
        for seq in seqs:
            dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                              global_batch=4,
                              embed_dim=cfg.d_model if cfg.frontend else 0)
            batch = make_batch(dcfg, 0)
            state, m = step(state, batch)  # compile
            jax.block_until_ready(m["loss"])

            def one_step():
                nonlocal state
                state, mm = step(state, batch)
                jax.block_until_ready(mm["loss"])

            best = time_run(one_step, repeats=repeats)
            gran = best / cfg.num_layers
            per_layer.append(gran)
            rows.append(Row(f"model_step.{arch}.seq{seq}", best * 1e6,
                            f"per_layer_task_us={gran * 1e6:.1f}"))

        # dispatch floor: empty jitted step
        @jax.jit
        def noop(x):
            return x + 1

        x = jax.numpy.zeros(())
        noop(x)
        t0 = time.perf_counter()
        for _ in range(100):
            x = noop(x)
        jax.block_until_ready(x)
        floor = (time.perf_counter() - t0) / 100
        rows.append(Row(f"model_step.{arch}.dispatch_floor", floor * 1e6,
                        f"min_layer_task_us={min(per_layer) * 1e6:.1f};"
                        f"framework_overhead_ratio="
                        f"{floor / max(min(per_layer), 1e-9):.3f}"))
    return rows
