"""Framework METG: the paper's metric applied to our own runtime.

Treats one transformer block as the "task" and sweeps model size (layer
count fixed, per-layer work varied via seq length) measuring the train-step
dispatch floor — the granularity below which the JAX dispatch overhead
(python + runtime) eats >50% of the step.  This is the number a user needs
to pick microbatch sizes on real hardware, and the direct analogue of the
paper's §V-C question asked of this framework itself.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, make_batch
from repro.train import train_step as TS

from .common import Row

ARCHS = ["qwen1.5-0.5b", "mixtral-8x7b", "mamba2-2.7b"]


def run() -> List[Row]:
    rows: List[Row] = []
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        tcfg = TS.TrainConfig(total_steps=100)
        state, _ = TS.init_state(jax.random.PRNGKey(0), cfg, tcfg)
        step = TS.jit_train_step(cfg, tcfg)
        per_layer = []
        for seq in (16, 64, 256):
            dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                              global_batch=4,
                              embed_dim=cfg.d_model if cfg.frontend else 0)
            batch = make_batch(dcfg, 0)
            state, m = step(state, batch)  # compile
            jax.block_until_ready(m["loss"])
            times = []
            for _ in range(3):
                t0 = time.perf_counter()
                state, m = step(state, batch)
                jax.block_until_ready(m["loss"])
                times.append(time.perf_counter() - t0)
            best = min(times)
            gran = best / cfg.num_layers
            per_layer.append(gran)
            rows.append(Row(f"model_step.{arch}.seq{seq}", best * 1e6,
                            f"per_layer_task_us={gran * 1e6:.1f}"))
        # dispatch floor: empty jitted step
        @jax.jit
        def noop(x):
            return x + 1

        x = jax.numpy.zeros(())
        noop(x)
        t0 = time.perf_counter()
        for _ in range(100):
            x = noop(x)
        jax.block_until_ready(x)
        floor = (time.perf_counter() - t0) / 100
        rows.append(Row(f"model_step.{arch}.dispatch_floor", floor * 1e6,
                        f"min_layer_task_us={min(per_layer) * 1e6:.1f};"
                        f"framework_overhead_ratio="
                        f"{floor / max(min(per_layer), 1e-9):.3f}"))
    return rows
