"""Paper Figures 4/5 (§V-D/E): weak-scaling efficiency vs granularity.

Fixed work per rank (``width_per_rank`` graph columns), rank count swept
over {1, 2, 4, 8} by relaunching a child process per rank count with the
JAX device count pinned (``repro.bench.scaling`` — JAX fixes its device
count at process start, so a sweep cannot happen in-process).  Each
(backend, ranks) cell runs the ordinary METG sweep; the assembled
``kind="metg_scaling"`` artifact records per-rank elapsed, weak-scaling
efficiency ``T(1)/T(n)``, and the efficiency-vs-granularity contour —
the paper's scaling study compressed against the overhead floor.

Backends: only those whose ``CommPlan`` paths are multi-rank
(``shardmap-csp``/``shardmap-pipeline``, each also in ``comm=onesided``
mode, plus the ``auto`` planner).  Single-device backends would measure
nothing under a rank sweep.

Supersedes the single-device ``bench_scaling.py`` (wall time vs per-task
size at fixed shape), whose contour is subsumed by this family's
rank-1 cell.
"""
from __future__ import annotations

from typing import List

from repro.bench.scaling import RANKS, SCALING_BACKENDS, ScalingSpec

from .common import BenchContext, Row

# artifact-friendly scenario labels (spec option brackets make ugly slugs)
_LABELS = {
    "shardmap-csp": "shardmap-csp",
    "shardmap-csp[comm=onesided]": "shardmap-csp.onesided",
    "shardmap-pipeline": "shardmap-pipeline",
    "shardmap-pipeline[comm=onesided]": "shardmap-pipeline.onesided",
    "auto": "auto",
}


def _label(backend: str) -> str:
    return _LABELS.get(backend, backend.replace("[", ".").replace("]", ""))


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    backends = [b for b in SCALING_BACKENDS if ctx.wants_backend(b)]
    if not backends:
        # zero cells exiting 0 would green-light a typo'd --backends
        # filter; name both sides of the mismatch
        raise ValueError(
            f"--backends filter {ctx.backends!r} matches none of this "
            f"family's backends {list(SCALING_BACKENDS)}")
    rows: List[Row] = []
    for be in backends:
        spec = ScalingSpec(name=f"metg_scaling.{_label(be)}", backend=be,
                           ranks=RANKS)
        res = ctx.run_scaling(spec)
        for c in res.cells:
            rows.append(Row(
                f"{spec.name}.r{c['ranks']}",
                c["elapsed_s"] * 1e6,
                f"width={c['width']};weak_eff={c['weak_efficiency']:.3f};"
                f"granularity_us={c['granularity_s'] * 1e6:.2f}"))
    return rows
