"""Paper Figure 11: efficiency vs task granularity for varying payloads.

Spread pattern, 5 deps/task, 4 concurrent graphs (through ``run_many``);
``output_bytes`` sweeps the communication volume per dependency.  Compares
the CSP backend (strict compute/communicate alternation, like MPI) against
the whole-graph dataflow backend (XLA free to overlap/fuse) — the paper's
asynchronous-systems-win-under-communication finding.  Thin wrapper over
``repro.bench``.
"""
from __future__ import annotations

from typing import List

from .common import BenchContext, Row, metg_for

BYTES = [16, 4096, 65536]


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    rows: List[Row] = []
    for be in ("shardmap-csp", "xla-static"):
        for ob in BYTES:
            res = metg_for(ctx, be, "spread",
                           name=f"overlap.{be}.bytes{ob}",
                           radix=5, num_graphs=4, output_bytes=ob,
                           iterations_hi=4096, n_points=6, height=24)
            for p in sorted(res.points, key=lambda p: -p.iterations):
                rows.append(Row(
                    f"overlap.{be}.bytes{ob}.iters{p.iterations}",
                    p.granularity * 1e6,
                    f"eff={p.efficiency:.3f}"))
            rows.append(Row(f"overlap.{be}.bytes{ob}.METG",
                            (res.metg or float("nan")) * 1e6,
                            f"peak={res.peak_rate:.4g}"))
    return rows
