"""Paper Figure 9: METG(50%) per backend per dependence pattern.

Patterns as in §V-C: (a) stencil, (b) nearest with 5 deps, (c) spread with
5 deps, (d) 4 concurrent nearest graphs (task parallelism, executed
concurrently through ``Backend.run_many``).  All backends run all cases —
the O(m+n) property in action.  Thin wrapper over ``repro.bench``.
"""
from __future__ import annotations

from typing import List

from repro.backends import backend_names

from .common import BenchContext, Row, metg_for

CASES = [
    ("stencil", {}, 1),
    ("nearest", {"radix": 5}, 1),
    ("spread", {"radix": 5}, 1),
    ("nearest_x4", {"radix": 5}, 4),
]


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    backends = [b for b in backend_names() if ctx.wants_backend(b)]
    if not backends:
        raise ValueError(
            f"--backends filter {ctx.backends!r} matches none of the "
            f"registered backends {backend_names()}")
    rows: List[Row] = []
    for be in backends:
        hi = 1024 if be == "host-dynamic" else 4096
        for case, kw, ngraphs in CASES:
            pattern = "nearest" if case == "nearest_x4" else case
            res = metg_for(ctx, be, pattern, name=f"metg.{be}.{case}",
                           num_graphs=ngraphs, iterations_hi=hi,
                           n_points=6, **kw)
            metg_us = (res.metg or float("nan")) * 1e6
            rows.append(Row(f"metg.{be}.{case}", metg_us,
                            f"peak_flops_per_s={res.peak_rate:.4g}"))
    return rows
