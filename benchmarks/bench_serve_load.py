"""serve_load family: open-loop serving latency under increasing load.

Six cells: {host, chunked} decode x three arrival rates, one seeded
open-loop trace each (see ``repro.bench.serve``).  Under the default
wall clock this drives the real ``ServeEngine`` on a reduced model; under
``--timer synthetic`` it runs the deterministic discrete-event cost model
— the committed-baseline path, where the host mode's one-sync-per-token
tax vs the chunked engine's one-sync-per-chunk is exact arithmetic.

The rates ladder from arrival-limited (both modes mostly idle between
requests) to saturated (the host mode queues hard, TTFT blows up), so the
artifact set traces how the sync floor caps decode throughput — the
serving rendition of the paper's §IV-B overhead wall.
"""
from __future__ import annotations

from typing import List

from repro.bench.serve import ServeLoadSpec

from .common import BenchContext, Row

RATES = (500.0, 2000.0, 8000.0)


def specs() -> List[ServeLoadSpec]:
    return [
        ServeLoadSpec(
            name=f"serve_load.{mode}.rate{int(rate)}",
            mode=mode, rate_rps=rate, num_requests=64,
            batch_slots=4, chunk_size=8, max_len=64,
            prompt_len=(4, 8), out_tokens=(4, 24), seed=0)
        for mode in ("host", "chunked")
        for rate in RATES
    ]


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    rows = []
    for spec in specs():
        m = ctx.run_serve(spec).metrics
        rows.append(Row(
            spec.name, m["tpot_s"]["p50"] * 1e6,
            f"thr={m['throughput_tok_s']:.0f}tok/s "
            f"ttft_p95={m['ttft_s']['p95'] * 1e3:.3f}ms "
            f"syncs/tok={m['host_syncs_per_token']:.3f}"))
    return rows
