"""MoE dispatch comm volume: SP-aware EP vs token replication.

The `moe_dispatch` scenario (``repro.bench.moe``) measured the dry-run
way: per-plane all-to-all bytes from the exact capacity math the kernel
uses, scored against the interconnect roofline (``launch.roofline``).
The headline number is the reduction ratio — SP-aware expert parallelism
(``ep_mode="sp"``) moves 1/|model| of the replicated volume per plane
(asserted, not just printed, in ``tests/test_bench.py`` and on the
compiled HLO in ``tests/test_distributed.py``).

When the local runtime has enough devices the compiled-HLO bytes are
reported alongside the analytic model; on the 1-device CI runtime only
the analytic numbers appear (they are verified equal to the HLO by the
8-device tests).
"""
from __future__ import annotations

from typing import List

import jax

from repro.bench import MoEDispatchSpec, moe_dispatch_report

from .common import BenchContext, Row

MESHES = [(4, 2), (2, 4)]          # (data, model)
SMOKE_MESHES = [(4, 2)]


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    rows: List[Row] = []
    for data, model in (SMOKE_MESHES if ctx.smoke else MESHES):
        reports = {}
        for ep_mode in ("replicated", "sp"):
            spec = MoEDispatchSpec(data=data, model=model, ep_mode=ep_mode)
            compiled = len(jax.devices()) >= data * model
            rep = moe_dispatch_report(spec, compiled=compiled)
            reports[ep_mode] = rep
            derived = (f"a2a_bytes={rep['a2a_bytes']:.0f};"
                       f"cap={rep['cap']:.0f};"
                       f"planes={rep['dispatch_planes']:.0f}")
            if "hlo_a2a_bytes" in rep:
                derived += f";hlo_a2a_bytes={rep['hlo_a2a_bytes']:.0f}"
            rows.append(Row(f"moe_dispatch.d{data}m{model}.{ep_mode}",
                            rep["a2a_roofline_s"] * 1e6, derived))
        ratio = (reports["replicated"]["a2a_bytes"]
                 / reports["sp"]["a2a_bytes"])
        rows.append(Row(f"moe_dispatch.d{data}m{model}.reduction", 0.0,
                        f"a2a_ratio={ratio:.2f};model_axis={model}"))
    return rows
