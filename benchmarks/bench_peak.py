"""Paper Figures 2/6 (compute) and 8 (memory): peak rate vs problem size.

Sweeps task duration at fixed graph shape and reports achieved FLOP/s
(compute kernel) and B/s (memory kernel, constant working set) — the
100%-efficiency baselines every METG below is measured against.  Thin
wrapper over ``repro.bench`` scenarios with an explicit sweep schedule.
"""
from __future__ import annotations

from typing import List

from repro.bench import ScenarioSpec, SweepControls, geometric_iterations

from .common import BenchContext, Row


def _sweep(ctx: BenchContext, kernel: str, iterations_hi: int,
           **graph_kw) -> List[Row]:
    spec = ScenarioSpec(
        name=f"peak.{kernel}",
        backend="xla-scan",
        pattern="stencil",
        kernel=kernel,
        width=8,
        height=32,
        graph_kw=tuple(sorted(graph_kw.items())),
        sweep=SweepControls(
            schedule=tuple(geometric_iterations(iterations_hi, 4, 4.0))),
    )
    res = ctx.run(spec).metg
    unit = "flops" if kernel == "compute" else "bytes"
    rows = [
        Row(f"peak_{kernel}.iters{p.iterations}",
            p.granularity * 1e6,
            f"rate_{unit}_per_s={p.rate:.4g};eff={p.efficiency:.3f}")
        for p in res.points
    ]
    rows.append(Row(f"peak_{kernel}.PEAK", 0.0,
                    f"peak_{unit}_per_s={res.peak_rate:.4g};"
                    f"metg50_us={(res.metg or 0) * 1e6:.2f}"))
    return rows


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    rows = _sweep(ctx, "compute", 65536)
    rows += _sweep(ctx, "memory", 2048, span_bytes=16 * 1024,
                   scratch_bytes=1 << 20)
    return rows
