"""Paper Figures 2/6 (compute) and 8 (memory): peak rate vs problem size.

Sweeps task duration at fixed graph shape and reports achieved FLOP/s
(compute kernel) and B/s (memory kernel, constant working set) — the
100%-efficiency baselines every METG below is measured against.
"""
from __future__ import annotations

from typing import List

from repro.backends import get_backend
from repro.core import compute_metg, geometric_iterations, make_graph, run_sweep

from .common import Row


def _sweep(kernel: str, iterations_hi: int, **kw) -> List[Row]:
    be = get_backend("xla-scan")

    def graphs_at(iters):
        return [make_graph(width=8, height=32, pattern="stencil",
                           kernel=kernel, iterations=iters, **kw)]

    def make_runner(iters):
        return be.prepare(graphs_at(iters))

    iters_list = geometric_iterations(iterations_hi, 4, 4.0)
    pts = run_sweep(make_runner, graphs_at, iters_list, repeats=3)
    res = compute_metg(pts)
    unit = "flops" if kernel == "compute" else "bytes"
    rows = [
        Row(f"peak_{kernel}.iters{p.iterations}",
            p.granularity * 1e6,
            f"rate_{unit}_per_s={p.rate:.4g};eff={p.efficiency:.3f}")
        for p in res.points
    ]
    rows.append(Row(f"peak_{kernel}.PEAK", 0.0,
                    f"peak_{unit}_per_s={res.peak_rate:.4g};"
                    f"metg50_us={(res.metg or 0) * 1e6:.2f}"))
    return rows


def run() -> List[Row]:
    rows = _sweep("compute", 65536)
    rows += _sweep("memory", 2048, span_bytes=16 * 1024,
                   scratch_bytes=1 << 20)
    return rows
