"""Campaign CLI: run a declarative TOML benchmark suite.

``python -m benchmarks.suite benchmarks/suites/paper.toml --smoke
--timer``-free: the timer lives in the TOML (suite default + per-cell
override).  Each cell is one ``python -m benchmarks.run --only <family>``
subprocess (``repro.bench.suite``), so the artifacts are the ones a
serial run writes — bit-identical on the synthetic timer, which the
per-cell ``rollouts`` byte-comparison enforces.

Exit codes: 2 = the suite file is invalid (TOML syntax, unknown family
or backend — nothing was run); 1 = a cell failed, a rollout mismatched,
or the ``--baseline`` gate found a regression; 0 = clean campaign.

``--tables`` splices the aggregated summary into EXPERIMENTS.md only on
a fully green campaign (a partial artifact set must not regenerate the
committed tables — same rule as ``run.py``).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> None:
    from repro.bench.compare import (bench_json_names, compare_dirs,
                                     format_report, scenario_family)
    from repro.bench.suite import load_suite, run_suite, validate_suite

    from .run import MODULES

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("suite", help="TOML suite file (benchmarks/suites/)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweeps for CI (forwarded to every cell)")
    ap.add_argument("--artifacts", default="results/suite",
                    help="directory for the campaign's BENCH_*.json")
    ap.add_argument("--parallel", type=int, default=None,
                    help="override the suite's parallel cell count")
    ap.add_argument("--baseline", default=None,
                    help="directory of committed BENCH_*.json to diff the "
                         "campaign against; exit nonzero on regression")
    ap.add_argument("--baseline-threshold", type=float, default=0.25,
                    help="relative slowdown tolerated by --baseline")
    ap.add_argument("--tables", action="store_true",
                    help="aggregate the campaign's artifacts into the "
                         "paper-style tables (append_tables.py)")
    ap.add_argument("--tables-file", default="EXPERIMENTS.md",
                    help="markdown file --tables appends to")
    args = ap.parse_args(argv)

    try:
        suite = load_suite(args.suite)
        from repro.backends import backend_names

        validate_suite(suite, known_families=MODULES,
                       known_backends=backend_names())
    except (OSError, ValueError) as e:
        print(f"suite: {e}", file=sys.stderr)
        sys.exit(2)

    result = run_suite(suite, args.artifacts, smoke=args.smoke,
                       parallel=args.parallel)
    # the cells' CSV output is part of the campaign record — replay it
    # serially (one block per cell) so `suite ... | tee` is as greppable
    # as a serial run
    print("name,us_per_call,derived")
    for run in result.runs:
        for line in run.stdout.splitlines():
            if line and line != "name,us_per_call,derived":
                print(line)
    for label, detail in result.failures:
        print(f"suite,0,FAILED {label}: {detail.splitlines()[-1] if detail else ''}",
              flush=True)
        if detail:
            print(f"suite: cell {label} failed:\n{detail}", file=sys.stderr)
    for line in result.summary().splitlines():
        print(f"suite,0,{line}", flush=True)

    if args.tables and not result.ok:
        print(f"suite: skipping --tables splice into {args.tables_file}: "
              f"the campaign is red and the artifact set is partial",
              file=sys.stderr)
    elif args.tables:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        if root not in sys.path:
            sys.path.insert(0, root)
        import append_tables

        tpath, skipped = append_tables.append_metg_tables(
            args.artifacts, args.tables_file)
        note = f" ({skipped} invalid artifact(s) skipped)" if skipped else ""
        print(f"tables,0,{tpath}{note}", flush=True)

    regressed = False
    if args.baseline:
        # gate the scenario families this campaign actually produced;
        # baseline families outside the suite were not run, and "missing"
        # would misread as "vanished" (same scoping as run.py --only)
        fams = {scenario_family(f)
                for f in bench_json_names(result.out_dir)}
        skipped_fams = sorted({scenario_family(f)
                               for f in bench_json_names(args.baseline)
                               if scenario_family(f) not in fams})
        if skipped_fams:
            print(f"compare,0,skipping baseline families outside this "
                  f"campaign: {skipped_fams}", flush=True)
        results = compare_dirs(args.baseline, result.out_dir,
                               rel_threshold=args.baseline_threshold,
                               families=fams)
        for line in format_report(results).splitlines():
            print(f"compare,0,{line}", flush=True)
        regressed = any(not r.ok for r in results)

    if not result.ok or regressed:
        sys.exit(1)


if __name__ == "__main__":
    main()
