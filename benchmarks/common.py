"""Shared benchmark infrastructure — thin glue over ``repro.bench``.

Every bench module exposes ``run(ctx) -> List[Row]``; ``benchmarks.run``
aggregates and prints ``name,us_per_call,derived`` CSV (one row per
measurement the paper's corresponding table/figure would plot), while the
``BenchContext`` writes a schema-checked ``BENCH_<scenario>.json`` per
scenario so the perf trajectory is machine-readable across PRs.

Smoke mode is carried by the context and becomes a *parameter* of each
scenario's ``SweepControls`` (no module-level global): the resolved spec —
recorded in the artifact — is exactly what was measured.

CPU-runtime note (DESIGN.md §7): these are real wall-clock measurements of
the execution backends on the one-core CPU runtime — the paper's
comparative methodology (backends x patterns x granularity), not its Cori
absolute numbers.  Production-mesh numbers live in EXPERIMENTS.md
§Roofline, derived from the compiled dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.bench import (METGResult, ScenarioResult, ScenarioSpec,
                         SweepControls, Timer, run_scenario, write_bench_json)
from repro.bench.artifact import artifact_path


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


@dataclasses.dataclass
class BenchContext:
    """Per-invocation knobs: smoke mode, artifact sink, timer override."""

    smoke: bool = False
    artifacts_dir: Optional[str] = None
    timer: Optional[Timer] = None  # None -> wall clock from sweep controls
    written: List[str] = dataclasses.field(default_factory=list)
    # None -> every backend a module defines; otherwise an explicit spec
    # filter (``--backends``) matched canonically via ``wants_backend``
    backends: Optional[List[str]] = None

    def wants_backend(self, spec: str) -> bool:
        """Whether ``spec`` survives the ``--backends`` filter.

        Matching is canonical (option order inside the spec string is not
        identity), falling back to raw string equality for specs the
        parser rejects — a typo'd filter entry should match nothing, not
        crash the registry run.
        """
        if self.backends is None:
            return True
        from repro.backends.base import canonical_backend_spec

        def canon(s: str) -> str:
            try:
                return canonical_backend_spec(s)
            except ValueError:
                return s

        want = {canon(b) for b in self.backends}
        return canon(spec) in want

    def run(self, spec: ScenarioSpec, peak_rate: Optional[float] = None,
            timer: Optional[Timer] = None) -> ScenarioResult:
        """Measure one scenario (smoke applied) and record its artifact.

        ``timer`` overrides the context timer for this scenario — the
        study families specialize the synthetic clock (worker pools,
        bytes-per-second) without forking the context.
        """
        spec = spec.with_smoke(self.smoke or spec.sweep.smoke)
        if self.artifacts_dir:
            # fail before measuring (and before the earlier artifact would
            # be clobbered): distinct names must map to distinct slugs
            path = artifact_path(spec.slug, self.artifacts_dir)
            if path in self.written:
                raise ValueError(
                    f"scenario {spec.name!r} would overwrite an earlier "
                    f"artifact at {path}; pick names with distinct slugs")
        result = run_scenario(spec, timer=timer if timer is not None
                              else self.timer, peak_rate=peak_rate)
        if self.artifacts_dir:
            self.written.append(write_bench_json(result, self.artifacts_dir))
        return result

    def run_serve(self, spec, **kw):
        """Measure one serve_load cell (smoke applied), record its artifact.

        Dispatches on the context timer: None/wallclock drives the real
        ``ServeEngine``; the synthetic fake clock runs the deterministic
        discrete-event cost model (the CI-gated baseline path).  ``kw``
        forwards to ``run_serve_load`` (e.g. ``cost=ServeCostParams(...)``).
        """
        from repro.bench.serve import run_serve_load, write_serve_json

        spec = spec.resolved(self.smoke or spec.smoke)
        if self.artifacts_dir:
            path = artifact_path(spec.slug, self.artifacts_dir)
            if path in self.written:
                raise ValueError(
                    f"scenario {spec.name!r} would overwrite an earlier "
                    f"artifact at {path}; pick names with distinct slugs")
        result = run_serve_load(spec, timer=self.timer, **kw)
        if self.artifacts_dir:
            self.written.append(write_serve_json(result, self.artifacts_dir))
        return result

    def run_scaling(self, spec, **kw):
        """Run one weak-scaling rank sweep (smoke applied), record artifact.

        Each rank count of the sweep executes in a relaunched subprocess
        with the device count pinned (``repro.bench.scaling``); the
        context timer is serialized to the children, so ``--timer
        synthetic`` yields the deterministic machine-independent artifact
        the CI gate runs on.  ``kw`` forwards to ``run_scaling`` (e.g.
        ``python=`` for tests).
        """
        from repro.bench.scaling import run_scaling, write_scaling_json

        if self.artifacts_dir:
            path = artifact_path(spec.slug, self.artifacts_dir)
            if path in self.written:
                raise ValueError(
                    f"scenario {spec.name!r} would overwrite an earlier "
                    f"artifact at {path}; pick names with distinct slugs")
        result = run_scaling(spec, timer=self.timer, smoke=self.smoke, **kw)
        if self.artifacts_dir:
            self.written.append(
                write_scaling_json(result, self.artifacts_dir))
        return result


def metg_for(
    ctx: BenchContext,
    backend_name: str,
    pattern: str,
    name: Optional[str] = None,
    width: int = 8,
    height: int = 32,
    iterations_hi: int = 4096,
    n_points: int = 7,
    num_graphs: int = 1,
    kernel: str = "compute",
    output_bytes: int = 16,
    imbalance: float = 0.0,
    repeats: int = 3,
    threshold: float = 0.5,
    peak_rate: Optional[float] = None,
    **graph_kw,
) -> METGResult:
    """Run the paper's METG procedure for one (backend, pattern) cell."""
    spec = ScenarioSpec(
        name=name or f"metg.{backend_name}.{pattern}",
        backend=backend_name,
        pattern=pattern,
        kernel=kernel,
        width=width,
        height=height,
        output_bytes=output_bytes,
        imbalance=imbalance,
        ngraphs=num_graphs,
        graph_kw=tuple(sorted(graph_kw.items())),
        sweep=SweepControls(iterations_hi=iterations_hi, n_points=n_points,
                            repeats=repeats, threshold=threshold),
    )
    return ctx.run(spec, peak_rate=peak_rate).metg
