"""Shared benchmark infrastructure.

Every bench module exposes ``run() -> List[Row]``; ``benchmarks.run``
aggregates and prints ``name,us_per_call,derived`` CSV (one row per
measurement the paper's corresponding table/figure would plot).

CPU-runtime note (DESIGN.md §7): these are real wall-clock measurements of
the four execution backends on the one-core CPU runtime — the paper's
comparative methodology (backends x patterns x granularity), not its Cori
absolute numbers.  Production-mesh numbers live in EXPERIMENTS.md
§Roofline, derived from the compiled dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core import (TaskGraph, compute_metg, geometric_iterations,
                        make_graph, run_sweep)
from repro.backends import get_backend


# CI smoke mode (benchmarks/run.py --smoke): shrink every METG sweep to a
# few tiny points so the scripts stay exercised without real measurement.
SMOKE = False


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def metg_for(
    backend_name: str,
    pattern: str,
    width: int = 8,
    height: int = 32,
    iterations_hi: int = 4096,
    n_points: int = 7,
    num_graphs: int = 1,
    kernel: str = "compute",
    output_bytes: int = 16,
    imbalance: float = 0.0,
    repeats: int = 3,
    threshold: float = 0.5,
    peak_rate: Optional[float] = None,
    **graph_kw,
):
    """Run the paper's METG procedure for one (backend, pattern) cell."""
    if SMOKE:
        iterations_hi = min(iterations_hi, 64)
        n_points = min(n_points, 3)
        repeats = 1
        height = min(height, 8)
    be = get_backend(backend_name)

    def graphs_at(iters: int):
        g = make_graph(width=width, height=height, pattern=pattern,
                       kernel=kernel, iterations=iters,
                       output_bytes=output_bytes, imbalance=imbalance,
                       **graph_kw)
        return [g] * num_graphs

    def make_runner(iters: int):
        return be.prepare(graphs_at(iters))

    factor = max(2.0, (iterations_hi) ** (1.0 / max(n_points - 1, 1)))
    iters_list = geometric_iterations(iterations_hi, 1, factor)[:n_points]
    points = run_sweep(make_runner, graphs_at, iters_list, cores=1,
                       repeats=repeats)
    return compute_metg(points, threshold=threshold, peak_rate=peak_rate)
