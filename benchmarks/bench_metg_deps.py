"""Paper Figure 10: METG vs dependencies per task (nearest, radix 0..9).

The paper's headline: the 0->3 dependency step costs MPI 12x; dynamic
systems are hit hardest.  Here the same sweep contrasts the compiled
backend (xla-scan) with per-task host dispatch.  Thin wrapper over
``repro.bench``.
"""
from __future__ import annotations

from typing import List

from .common import BenchContext, Row, metg_for

RADII = [0, 1, 3, 5, 7, 9]


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    rows: List[Row] = []
    for be, hi in (("xla-scan", 4096), ("shardmap-csp", 4096),
                   ("host-dynamic", 1024)):
        base = None
        for r in RADII:
            res = metg_for(ctx, be, "nearest",
                           name=f"metg_deps.{be}.radix{r}",
                           radix=r, iterations_hi=hi, n_points=6, width=10)
            metg_us = (res.metg or float("nan")) * 1e6
            if r == 0:
                base = metg_us
            ratio = metg_us / base if base else float("nan")
            rows.append(Row(f"metg_deps.{be}.radix{r}", metg_us,
                            f"ratio_vs_radix0={ratio:.2f}"))
    return rows
