"""Benchmark registry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes one schema-checked
``BENCH_<scenario>.json`` per scenario (see ``repro.bench.artifact``) into
``--artifacts`` so the perf trajectory is collected across PRs.  Mapping to
the paper:

  bench_peak             Figures 2/6 (peak FLOP/s), Figure 8 (peak B/s)
  bench_metg_patterns    Figure 9 (METG x backend x pattern)
  bench_metg_deps        Figure 10 (METG vs deps/task)
  bench_overlap          Figure 11 (communication overlap)
  bench_imbalance        Figure 12 (load imbalance)
  bench_metg_scaling     Figures 4/5 (§V-D/E): weak-scaling efficiency,
                         rank sweep {1,2,4,8} via per-rank subprocess
                         relaunch with the JAX device count pinned
  bench_metg_validation  Figure 14 / Table 6 (METG predicts the limit)
  bench_model_step       §V-C applied to this framework's own dispatch
  bench_moe_dispatch     MoE dispatch comm volume (SP-aware EP vs
                         token replication, dry-run roofline)
  bench_metg_payload     §V-F study: communication hiding — payload sweep,
                         comm_overlap on/off (overlap-efficiency curve)
  bench_metg_imbalance   §V-G study: imbalance mitigation — work stealing
                         vs static schedule (mitigation-factor curve)
  bench_serve_load       serving under open-loop load: host-loop vs
                         chunked decode, TTFT/TPOT/goodput percentiles
                         (real engine on wallclock, deterministic cost
                         model on --timer synthetic)

Run all: ``PYTHONPATH=src python -m benchmarks.run``
One:     ``PYTHONPATH=src python -m benchmarks.run --only bench_metg_deps``
(``--only`` entries are validated against the registry above — a typo'd
module name exits nonzero instead of silently running zero benchmarks.)
Smoke (CI): ``... --smoke`` — tiny sweeps, one repeat, shallow graphs;
smoke is a parameter of each scenario's ``SweepControls``, not a global.

Tuning: ``--tune`` regenerates the backend-planner table consumed by
``get_backend("auto")`` (``repro.bench.tuner``) instead of running bench
modules — commit it with
``python -m benchmarks.run --tune --timer synthetic --artifacts
benchmarks/tuning``; ``--tune-baseline benchmarks/tuning`` diffs a
regenerated table against the committed one (CI runs this on the
``--smoke`` reduced grid, a strict key-subset of the full table).

Regression gate: ``--baseline <dir>`` diffs every written artifact against
the committed snapshot (``repro.bench.compare``) and exits nonzero when a
scenario regressed beyond ``--baseline-threshold``.  With
``--timer synthetic`` the sweep runs on the deterministic fake clock, so
the CI gate against ``benchmarks/baselines/`` is noise-free.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

MODULES = [
    "bench_peak",
    "bench_metg_patterns",
    "bench_metg_deps",
    "bench_overlap",
    "bench_imbalance",
    "bench_metg_scaling",
    "bench_metg_validation",
    "bench_model_step",
    "bench_moe_dispatch",
    "bench_metg_payload",
    "bench_metg_imbalance",
    "bench_serve_load",
]


def _run_tune(args) -> None:
    """``--tune``: regenerate the backend-planner tuning table.

    Races every legal backend/mode spec on the selected timer over the
    tuning corpus (reduced grid under ``--smoke``), writes the validated
    ``TUNE_default.json`` into ``--artifacts``, and — with
    ``--tune-baseline`` — diffs it against the committed table in the
    same spirit as the ``--baseline`` bench gate: a changed winner at a
    shared key exits nonzero, keys the reduced grid did not retune are
    non-fatal notes.
    """
    from repro.bench import SyntheticTimer, WallClockTimer
    from repro.bench.tuner import (TuningKey, build_tuning_table,
                                   diff_tuning_tables, key_slug,
                                   read_tuning_json, tuning_table_path,
                                   write_tuning_json)

    timer = (SyntheticTimer() if args.timer == "synthetic"
             else WallClockTimer())
    doc = build_tuning_table(timer=timer, smoke=args.smoke)
    print("name,us_per_call,derived")
    for e in doc["entries"]:
        print(f"tune.{key_slug(TuningKey(**e['key']))},"
              f"{e['elapsed_s'] * 1e6:.3f},"
              f"winner={e['winner']} margin=+{e['margin']:.1%} "
              f"candidates={len(e['candidates'])}", flush=True)
    path = write_tuning_json(doc, args.artifacts)
    print(f"artifact,0,{path}", flush=True)

    fatal = []
    if args.tune_baseline:
        bpath = args.tune_baseline
        if os.path.isdir(bpath):
            bpath = tuning_table_path(bpath)
        fatal, notes = diff_tuning_tables(read_tuning_json(bpath), doc,
                                          subset_ok=args.smoke)
        for n in notes:
            print(f"tune-diff,0,{n}", flush=True)
        for f in fatal:
            print(f"tune-diff,0,FATAL {f}", flush=True)
        print(f"tune-diff,0,"
              + (f"{len(fatal)} fatal difference(s)" if fatal
                 else "winners match the committed table"), flush=True)
    if fatal:
        sys.exit(1)


def main(argv=None) -> None:
    from .common import BenchContext

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench module names")
    ap.add_argument("--backends", default=None,
                    help="comma-separated backend spec filter for the "
                         "modules that honor it (matched canonically; a "
                         "module whose filtered backend set is empty "
                         "raises, so a typo'd spec cannot green-light a "
                         "zero-cell run)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweeps for CI: few points, one repeat")
    ap.add_argument("--artifacts", default="results/bench",
                    help="directory for BENCH_<scenario>.json artifacts "
                         "('' disables)")
    ap.add_argument("--timer", choices=("wallclock", "synthetic"),
                    default="wallclock",
                    help="wallclock: real runs; synthetic: deterministic "
                         "fake clock (machine-independent artifacts for "
                         "the --baseline gate)")
    ap.add_argument("--baseline", default=None,
                    help="directory of committed BENCH_*.json to diff "
                         "against; exit nonzero on regression")
    ap.add_argument("--baseline-threshold", type=float, default=0.25,
                    help="relative slowdown tolerated by --baseline")
    ap.add_argument("--tables", action="store_true",
                    help="aggregate this run's BENCH_*.json artifacts into "
                         "the paper-style METG summary table and append it "
                         "to --tables-file (via append_tables.py)")
    ap.add_argument("--tables-file", default="EXPERIMENTS.md",
                    help="markdown file --tables appends to")
    ap.add_argument("--tune", action="store_true",
                    help="regenerate the backend-planner tuning table "
                         "(repro.bench.tuner) instead of running bench "
                         "modules: races the legal backend/mode space on "
                         "the selected timer and writes TUNE_default.json "
                         "into --artifacts; --smoke tunes the reduced grid")
    ap.add_argument("--tune-baseline", default=None,
                    help="committed tuning table (TUNE_*.json file or its "
                         "directory) to diff the regenerated table "
                         "against; a changed winner exits nonzero")
    args = ap.parse_args(argv)
    if args.baseline and not args.artifacts:
        ap.error("--baseline requires --artifacts (the current run's "
                 "artifacts are what gets compared)")
    if args.tables and not args.artifacts:
        ap.error("--tables requires --artifacts (the tables aggregate "
                 "the written artifacts)")
    if args.tune_baseline and not args.tune:
        ap.error("--tune-baseline requires --tune (there is no current "
                 "table to diff otherwise)")
    if args.tune:
        if args.only:
            ap.error("--tune runs the planner sweep, not bench modules; "
                     "drop --only")
        if not args.artifacts:
            ap.error("--tune requires --artifacts (where TUNE_*.json "
                     "is written)")
        _run_tune(args)
        return
    mods = MODULES
    if args.only:
        mods = [m.strip() for m in args.only.split(",") if m.strip()]
        unknown = sorted(set(mods) - set(MODULES))
        if unknown or not mods:
            # a misspelled module silently running ZERO benchmarks (and
            # exiting 0, green-lighting CI) is the failure mode here —
            # name the bad entry and the registry
            ap.error(f"--only: unknown bench module(s) "
                     f"{', '.join(unknown) or '(empty)'}; known modules: "
                     f"{', '.join(MODULES)}")
    timer = None
    if args.timer == "synthetic":
        from repro.bench import SyntheticTimer

        timer = SyntheticTimer()
    backends = None
    if args.backends:
        backends = [b.strip() for b in args.backends.split(",") if b.strip()]
        if not backends:
            ap.error("--backends: empty filter")
    ctx = BenchContext(smoke=args.smoke,
                       artifacts_dir=args.artifacts or None,
                       timer=timer,
                       backends=backends)

    print("name,us_per_call,derived")
    failures = []
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(ctx)
        except Exception as e:  # keep the suite running
            failures.append((name, e))
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}", flush=True)
            continue
        for row in rows:
            print(row.csv(), flush=True)
        print(f"{name}.elapsed,{(time.time() - t0) * 1e6:.0f},", flush=True)
    for path in ctx.written:
        print(f"artifact,0,{path}", flush=True)

    if args.tables and failures:
        # a red run wrote only part of the artifact set; regenerating the
        # committed tables from it would silently drop the failed
        # families' rows
        print(f"run.py: skipping --tables splice into {args.tables_file}: "
              f"{len(failures)} bench module(s) failed and the artifact "
              f"set is partial", file=sys.stderr)
    elif args.tables:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        if root not in sys.path:
            sys.path.insert(0, root)
        import append_tables

        tpath, skipped = append_tables.append_metg_tables(
            args.artifacts, args.tables_file)
        note = f" ({skipped} invalid artifact(s) skipped)" if skipped else ""
        print(f"tables,0,{tpath}{note}", flush=True)

    regressed = False
    if args.baseline:
        from repro.bench import compare_dirs, format_report
        from repro.bench.compare import bench_json_names, scenario_family

        # a partial run (--only) only remeasures some scenario families;
        # gate just those — baselines outside them were not run, and
        # flagging them "missing" would fail every partial dev run
        fams = None
        if args.only:
            fams = {scenario_family(p) for p in ctx.written}
            skipped = [f for f in bench_json_names(args.baseline)
                       if scenario_family(f) not in fams]
            if skipped:
                print(f"compare,0,skipping {len(skipped)} baseline "
                      f"artifact(s) outside this partial run "
                      f"(families {sorted({scenario_family(f) for f in skipped})})",
                      flush=True)
        results = compare_dirs(args.baseline, args.artifacts,
                               rel_threshold=args.baseline_threshold,
                               families=fams)
        for line in format_report(results).splitlines():
            print(f"compare,0,{line}", flush=True)
        regressed = any(not r.ok for r in results)

    if failures or regressed:
        sys.exit(1)


if __name__ == "__main__":
    main()
