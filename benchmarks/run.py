"""Benchmark registry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes one schema-checked
``BENCH_<scenario>.json`` per scenario (see ``repro.bench.artifact``) into
``--artifacts`` so the perf trajectory is collected across PRs.  Mapping to
the paper:

  bench_peak             Figures 2/6 (peak FLOP/s), Figure 8 (peak B/s)
  bench_metg_patterns    Figure 9 (METG x backend x pattern)
  bench_metg_deps        Figure 10 (METG vs deps/task)
  bench_overlap          Figure 11 (communication overlap)
  bench_imbalance        Figure 12 (load imbalance)
  bench_scaling          Figures 4/5 (scaling contour = METG curve)
  bench_metg_validation  Figure 14 / Table 6 (METG predicts the limit)
  bench_model_step       §V-C applied to this framework's own dispatch

Run all: ``PYTHONPATH=src python -m benchmarks.run``
One:     ``PYTHONPATH=src python -m benchmarks.run --only bench_metg_deps``
Smoke (CI): ``... --smoke`` — tiny sweeps, one repeat, shallow graphs;
smoke is a parameter of each scenario's ``SweepControls``, not a global.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "bench_peak",
    "bench_metg_patterns",
    "bench_metg_deps",
    "bench_overlap",
    "bench_imbalance",
    "bench_scaling",
    "bench_metg_validation",
    "bench_model_step",
]


def main(argv=None) -> None:
    from .common import BenchContext

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench module names")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweeps for CI: few points, one repeat")
    ap.add_argument("--artifacts", default="results/bench",
                    help="directory for BENCH_<scenario>.json artifacts "
                         "('' disables)")
    args = ap.parse_args(argv)
    mods = args.only.split(",") if args.only else MODULES
    ctx = BenchContext(smoke=args.smoke,
                       artifacts_dir=args.artifacts or None)

    print("name,us_per_call,derived")
    failures = []
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(ctx)
        except Exception as e:  # keep the suite running
            failures.append((name, e))
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}", flush=True)
            continue
        for row in rows:
            print(row.csv(), flush=True)
        print(f"{name}.elapsed,{(time.time() - t0) * 1e6:.0f},", flush=True)
    for path in ctx.written:
        print(f"artifact,0,{path}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
