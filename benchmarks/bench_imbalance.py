"""Paper Figure 12: efficiency under load imbalance.

Task durations scaled by a deterministic uniform factor (paper §V-G);
nearest pattern, 5 deps, 4 concurrent graphs.  The vectorized backend
executes masked full-length loops (cannot exploit short tasks — the
BSP/MPI analogue); host dispatch runs true per-task durations and recovers
part of the imbalance, the paper's asynchronous-scheduling benefit.

Efficiency here is relative to each backend's own balanced peak (the
balanced scenario's ``peak_rate`` pins the imbalanced sweep's baseline),
so the derived column isolates the imbalance penalty.  Thin wrapper over
``repro.bench``.
"""
from __future__ import annotations

from typing import List

from .common import BenchContext, Row, metg_for


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    rows: List[Row] = []
    for be, hi in (("xla-scan", 4096), ("host-dynamic", 512)):
        base = metg_for(ctx, be, "nearest", name=f"imbalance.{be}.balanced",
                        radix=5, num_graphs=4, iterations_hi=hi,
                        n_points=5, height=16)
        imb = metg_for(ctx, be, "nearest", name=f"imbalance.{be}.imbalanced",
                       radix=5, num_graphs=4, iterations_hi=hi,
                       n_points=5, height=16, imbalance=1.0,
                       peak_rate=base.peak_rate)
        for p in sorted(imb.points, key=lambda p: -p.iterations):
            rows.append(Row(
                f"imbalance.{be}.iters{p.iterations}",
                p.granularity * 1e6, f"eff_vs_balanced_peak={p.efficiency:.3f}"))
        best_imb = max((p.rate for p in imb.points), default=0.0)
        rows.append(Row(
            f"imbalance.{be}.summary",
            (imb.metg or float("nan")) * 1e6,
            f"balanced_peak={base.peak_rate:.4g};imb_best={best_imb:.4g};"
            f"peak_retained={best_imb / base.peak_rate:.3f}"))
    return rows
