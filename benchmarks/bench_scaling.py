"""Paper Figures 4/5: weak/strong scaling contours vs the METG curve.

On the 1-core CPU runtime, wall time cannot drop with added columns, but
the paper's essential phenomenon — scaling curves compressing against the
overhead floor at small problem sizes, with the floor's contour equal to
the METG curve — is directly measurable: wall time vs per-task problem
size at fixed shape flattens exactly where granularity hits METG.
"""
from __future__ import annotations

from typing import List

from repro.backends import get_backend
from repro.core import compute_metg, make_graph, run_sweep

from .common import Row


def run() -> List[Row]:
    rows: List[Row] = []
    for width in (4, 16):
        be = get_backend("xla-scan")

        def graphs_at(iters, width=width):
            return [make_graph(width=width, height=32, pattern="stencil",
                               kernel="compute", iterations=iters)]

        def make_runner(iters):
            return be.prepare(graphs_at(iters))

        sizes = [4096, 1024, 256, 64, 16, 4, 1]
        pts = run_sweep(make_runner, graphs_at, sizes, repeats=3)
        res = compute_metg(pts)
        for p in sorted(res.points, key=lambda q: -q.iterations):
            rows.append(Row(
                f"scaling.w{width}.size{p.iterations}",
                p.wall_time * 1e6,
                f"granularity_us={p.granularity * 1e6:.2f};"
                f"eff={p.efficiency:.3f}"))
        rows.append(Row(f"scaling.w{width}.METG",
                        (res.metg or float("nan")) * 1e6,
                        f"floor_wall_us={(res.metg or 0) * 32 * width * 1e6:.1f}"))
    return rows
