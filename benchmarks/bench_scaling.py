"""Paper Figures 4/5: weak/strong scaling contours vs the METG curve.

On the 1-core CPU runtime, wall time cannot drop with added columns, but
the paper's essential phenomenon — scaling curves compressing against the
overhead floor at small problem sizes, with the floor's contour equal to
the METG curve — is directly measurable: wall time vs per-task problem
size at fixed shape flattens exactly where granularity hits METG.  Thin
wrapper over ``repro.bench`` scenarios with an explicit sweep schedule.
"""
from __future__ import annotations

from typing import List

from repro.bench import ScenarioSpec, SweepControls

from .common import BenchContext, Row

SIZES = (4096, 1024, 256, 64, 16, 4, 1)


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    rows: List[Row] = []
    for width in (4, 16):
        spec = ScenarioSpec(
            name=f"scaling.w{width}",
            backend="xla-scan",
            pattern="stencil",
            kernel="compute",
            width=width,
            height=32,
            sweep=SweepControls(schedule=SIZES),
        )
        res = ctx.run(spec).metg
        for p in sorted(res.points, key=lambda q: -q.iterations):
            rows.append(Row(
                f"scaling.w{width}.size{p.iterations}",
                p.wall_time * 1e6,
                f"granularity_us={p.granularity * 1e6:.2f};"
                f"eff={p.efficiency:.3f}"))
        num_tasks = res.points[0].num_tasks if res.points else 0
        rows.append(Row(f"scaling.w{width}.METG",
                        (res.metg or float("nan")) * 1e6,
                        f"floor_wall_us={(res.metg or 0) * num_tasks * 1e6:.1f}"))
    return rows
