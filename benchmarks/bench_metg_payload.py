"""Study: communication hiding vs payload bytes (paper §V-F, Fig. 11/12).

Payload-bytes sweep at fixed task granularity for the SPMD backends with
``comm_overlap`` off (blocking, strict MPI-style compute/communicate
alternation) and on (double-buffered: the next timestep's exchange is
issued ahead of the kernel body).  Derived metric: overlap efficiency =
ideal / observed elapsed, normalized per variant against its smallest-
payload cell — see ``repro.bench.studies``.

On the synthetic timer the communication term is deterministic
(``ndeps * bytes * SECONDS_PER_BYTE``) and an overlapping backend pays
``max(compute, comm)`` instead of the sum, so the committed baselines
show ``overlap <= blocking`` elapsed at every payload — the acceptance
claim ``tests/test_bench.py`` asserts.  Thin wrapper over
``repro.bench.studies``.
"""
from __future__ import annotations

from typing import List

from repro.bench.studies import (PAYLOAD_BYTES, SECONDS_PER_BYTE,
                                 elapsed_s, payload_curve, payload_spec,
                                 study_timer)

from .common import BenchContext, Row

BACKENDS = ("shardmap-csp", "shardmap-pipeline")


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    timer = study_timer(ctx.timer, seconds_per_byte=SECONDS_PER_BYTE)
    rows: List[Row] = []
    for backend in BACKENDS:
        results = {}
        for overlap in (False, True):
            for ob in PAYLOAD_BYTES:
                spec = payload_spec(backend=backend, comm_overlap=overlap,
                                    output_bytes=ob)
                key = (ob, "overlap" if overlap else "blocking")
                results[key] = ctx.run(spec, timer=timer)
        for pt in payload_curve(results):
            rows.append(Row(
                f"metg_payload.{backend}.{pt.variant}.bytes{int(pt.x)}",
                pt.elapsed_s * 1e6,
                f"overlap_eff={pt.metric:.3f}"))
        for ob in PAYLOAD_BYTES:
            blocking = elapsed_s(results[(ob, "blocking")])
            overlap = elapsed_s(results[(ob, "overlap")])
            rows.append(Row(
                f"metg_payload.{backend}.hiding.bytes{ob}",
                (blocking - overlap) * 1e6,
                f"speedup={blocking / overlap:.3f}"))
    return rows
