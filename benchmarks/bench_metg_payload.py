"""Study: communication hiding vs payload bytes (paper §V-F, Fig. 11/12).

Payload-bytes sweep at fixed task granularity for the SPMD backends over
the three-point communication-mode spectrum: ``comm_overlap`` off
(blocking, strict MPI-style compute/communicate alternation), on
(double-buffered: the next timestep's exchange is issued ahead of the
kernel body), and ``comm=onesided`` (put/signal: producers push straight
into consumer receive buffers, no rendezvous at all).  Derived metric:
overlap efficiency = ideal / observed elapsed, normalized per variant
against its smallest-payload cell — see ``repro.bench.studies``.

On the synthetic timer the communication term is deterministic
(``ndeps * (rendezvous + bytes * SECONDS_PER_BYTE)``, where one-sided
skips the rendezvous surcharge) and both the overlapping and one-sided
backends pay ``max(compute, comm)`` instead of the sum, so the committed
baselines show ``onesided <= overlap <= blocking`` elapsed at every
payload — the acceptance claim ``tests/test_bench.py`` asserts.  Thin
wrapper over ``repro.bench.studies``.
"""
from __future__ import annotations

from typing import List

from repro.bench.studies import (PAYLOAD_BYTES, PAYLOAD_VARIANTS,
                                 SECONDS_PER_BYTE, SECONDS_PER_RENDEZVOUS,
                                 elapsed_s, payload_curve, payload_spec,
                                 study_timer)

from .common import BenchContext, Row

BACKENDS = ("shardmap-csp", "shardmap-pipeline")


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    timer = study_timer(ctx.timer, seconds_per_byte=SECONDS_PER_BYTE,
                        seconds_per_rendezvous=SECONDS_PER_RENDEZVOUS)
    rows: List[Row] = []
    for backend in BACKENDS:
        results = {}
        for variant in PAYLOAD_VARIANTS:
            for ob in PAYLOAD_BYTES:
                spec = payload_spec(backend=backend, output_bytes=ob,
                                    variant=variant)
                results[(ob, variant)] = ctx.run(spec, timer=timer)
        for pt in payload_curve(results):
            rows.append(Row(
                f"metg_payload.{backend}.{pt.variant}.bytes{int(pt.x)}",
                pt.elapsed_s * 1e6,
                f"overlap_eff={pt.metric:.3f}"))
        for ob in PAYLOAD_BYTES:
            blocking = elapsed_s(results[(ob, "blocking")])
            overlap = elapsed_s(results[(ob, "overlap")])
            onesided = elapsed_s(results[(ob, "onesided")])
            rows.append(Row(
                f"metg_payload.{backend}.hiding.bytes{ob}",
                (blocking - overlap) * 1e6,
                f"speedup={blocking / overlap:.3f}"))
            rows.append(Row(
                f"metg_payload.{backend}.onesided_gain.bytes{ob}",
                (blocking - onesided) * 1e6,
                f"speedup={blocking / onesided:.3f}"))
    return rows
