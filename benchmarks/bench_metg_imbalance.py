"""Study: load-imbalance mitigation vs schedule (paper §V-G, Fig. 12/13).

Imbalance-factor sweep at fixed task granularity for ``host-dynamic``
under its two executor schedules: static column ownership vs greedy
work stealing (``schedule="steal"``).  Derived metric: mitigation factor
= observed rate / the same schedule's balanced rate — see
``repro.bench.studies``.

On the synthetic timer (``workers=4`` plus a per-iteration rate that
makes task work dominate dispatch overhead) the wavefront makespans are
deterministic, so the committed baselines show the stealing schedule's
strictly better mitigation factor at imbalance=2.0 — the acceptance
claim ``tests/test_bench.py`` asserts.  Thin wrapper over
``repro.bench.studies``.
"""
from __future__ import annotations

from typing import List

from repro.bench.studies import (IMBALANCE_FACTORS,
                                 IMBALANCE_SECONDS_PER_ITERATION,
                                 IMBALANCE_VARIANTS, STUDY_WORKERS,
                                 imbalance_spec, mitigation_curve,
                                 study_timer)

from .common import BenchContext, Row


def run(ctx: BenchContext = None) -> List[Row]:
    ctx = ctx or BenchContext()
    timer = study_timer(
        ctx.timer, workers=STUDY_WORKERS,
        seconds_per_iteration=IMBALANCE_SECONDS_PER_ITERATION)
    rows: List[Row] = []
    results = {}
    for schedule in IMBALANCE_VARIANTS:
        for imb in (0.0,) + IMBALANCE_FACTORS:
            spec = imbalance_spec(schedule=schedule, imbalance=imb)
            results[(imb, schedule)] = ctx.run(spec, timer=timer)
    curve = mitigation_curve(results)
    for pt in curve:
        rows.append(Row(
            f"metg_imbalance.host-dynamic.{pt.variant}.imb{pt.x}",
            pt.elapsed_s * 1e6,
            f"mitigation={pt.metric:.3f}"))
    by_key = {(pt.x, pt.variant): pt.metric for pt in curve}
    for imb in IMBALANCE_FACTORS:
        static, steal = by_key[(imb, "static")], by_key[(imb, "steal")]
        rows.append(Row(
            f"metg_imbalance.host-dynamic.advantage.imb{imb}",
            0.0,
            f"steal_over_static={steal / static:.3f}"))
    return rows
